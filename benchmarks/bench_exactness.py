"""Paper D3 (exact replication): max |Δ| between Hydra-pipelined and
sequential per-trial training — losses and final parameters (subprocess,
8 fake devices)."""
import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run() -> list[dict]:
    rows = []
    for arch in ("chatglm3-6b", "falcon-mamba-7b"):
        proc = subprocess.run(
            [sys.executable, "tests/integration/pipeline_exactness.py", arch],
            env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src"),
                 "XLA_FLAGS": "--xla_force_host_platform_device_count=8"},
            capture_output=True, text=True, timeout=580, cwd=ROOT)
        m = re.search(r"loss_err=([\d.e+-]+) param_err=([\d.e+-]+)",
                      proc.stdout)
        if proc.returncode != 0 or not m:
            rows.append({"name": f"exactness/{arch}", "us_per_call": -1,
                         "derived": {"stderr": proc.stderr[-300:]}})
            continue
        rows.append({
            "name": f"exactness/{arch}",
            "us_per_call": float(m.group(1)),
            "derived": {"loss_err": float(m.group(1)),
                        "param_err_after_3_steps": float(m.group(2)),
                        "paper_desideratum": "exact replication (D3)"},
        })
    return rows
