"""Bench-regression wall: diff a fresh ``BENCH_serve.json`` against the
committed baseline and fail on throughput regressions.

The flat ``{row, metric, value, units}`` trajectory written by
``benchmarks/run.py --json`` is committed at the repo root as the
reference point. CI snapshots that committed file before the smoke bench
runs (the run overwrites it in the workspace when green), then calls

    python benchmarks/diff_bench_serve.py BASELINE FRESH

Gated metrics are the serve throughput numbers — ``tokens_per_s*`` /
``tokens_per_tick*`` (higher is better) and ``us_per_call`` (lower is
better). Any gated metric moving more than ``--threshold`` (default 15%)
in the bad direction fails the diff with exit 1. Gated metrics present
only in the fresh file (a bench row added by the PR under test) are
reported as ``NEW`` and never fail — a growing suite must not be walled
out by its own baseline. ``acceptance_rate`` entries are tracked as
``INFO`` (drafter quality context for the speculation row, not a gate).
Everything else in the trajectory is informational. A before/after
markdown table is appended to ``$GITHUB_STEP_SUMMARY`` when that variable
is set (or ``--summary PATH``).

``--self-test`` exercises the wall itself: a synthetic 20% throughput drop
must fail, an unchanged trajectory must pass, and fresh-only rows must
surface as NEW without failing, so a broken comparator can never
rubber-stamp a real regression.
"""
import argparse
import json
import os
import sys

DEFAULT_THRESHOLD = 0.15

# (metric-name substring, higher_is_better) — first match wins; metrics
# matching nothing are reported but never gated
GATED = (
    ("tokens_per_s", True),
    ("tokens_per_tick", True),
    ("us_per_call", False),
)

# reported alongside the gated metrics for context, never gated (drafter
# quality moves the speculation row's acceptance, not its correctness)
INFO = ("acceptance_rate",)


def gated_direction(metric):
    for sub, higher_is_better in GATED:
        if sub in metric:
            return higher_is_better
    return None


def load(path):
    with open(path) as f:
        recs = json.load(f)
    return {(r["row"], r["metric"]): float(r["value"]) for r in recs}


def diff(base, fresh, threshold=DEFAULT_THRESHOLD):
    """Compare two flat trajectories. Returns (entries, failures): entries
    are (row, metric, before, after, delta_frac, flag) for every gated or
    INFO metric present in the fresh file — flag is "" (within the wall),
    "REGRESSED" (gated move past the threshold in the bad direction), "NEW"
    (absent from the baseline: reported, never failed), or "INFO" (tracked
    for context, never gated). failures is the REGRESSED subset."""
    entries = []
    for key in sorted(fresh):
        row, metric = key
        higher_is_better = gated_direction(metric)
        info = any(sub in metric for sub in INFO)
        if higher_is_better is None and not info:
            continue
        after = fresh[key]
        if key not in base:
            entries.append((row, metric, None, after, None, "NEW"))
            continue
        before = base[key]
        if before == 0:
            continue  # no meaningful relative delta
        delta = (after - before) / abs(before)
        if info or higher_is_better is None:
            flag = "INFO"
        else:
            regressed = (delta < -threshold if higher_is_better
                         else delta > threshold)
            flag = "REGRESSED" if regressed else ""
        entries.append((row, metric, before, after, delta, flag))
    failures = [e for e in entries if e[5] == "REGRESSED"]
    return entries, failures


def render_markdown(entries, failures, threshold):
    lines = ["## serve bench regression wall",
             "",
             f"threshold: {threshold:.0%} on gated throughput metrics "
             f"({len(failures)} regression(s), {len(entries)} compared)",
             "",
             "| row | metric | baseline | fresh | delta | |",
             "|---|---|---:|---:|---:|---|"]
    for row, metric, before, after, delta, flag in entries:
        b = "—" if before is None else f"{before:g}"
        dl = "—" if delta is None else f"{delta:+.1%}"
        lines.append(f"| {row} | {metric} | {b} | {after:g} | {dl} "
                     f"| {flag} |")
    return "\n".join(lines) + "\n"


def self_test():
    """The wall must catch a synthetic 20% drop, pass a clean rerun, and
    report fresh-only rows as NEW without failing."""
    base = {
        ("serve/x", "tokens_per_s_fused"): 100.0,
        ("serve/x", "us_per_call"): 50.0,
        ("serve/x", "decode_occupancy_fused"): 0.9,  # not gated
    }
    same = dict(base)
    entries, failures = diff(base, same)
    assert len(entries) == 2 and not failures, \
        f"clean rerun flagged: {failures}"
    dropped = dict(base)
    dropped[("serve/x", "tokens_per_s_fused")] = 80.0  # -20% throughput
    _, failures = diff(base, dropped)
    assert [f[1] for f in failures] == ["tokens_per_s_fused"], \
        f"20% tok/s drop not caught: {failures}"
    slower = dict(base)
    slower[("serve/x", "us_per_call")] = 60.0  # +20% per-call cost
    _, failures = diff(base, slower)
    assert [f[1] for f in failures] == ["us_per_call"], \
        f"20% us/call increase not caught: {failures}"
    within = dict(base)
    within[("serve/x", "tokens_per_s_fused")] = 90.0  # -10%: inside the wall
    _, failures = diff(base, within)
    assert not failures, f"10% drop wrongly flagged: {failures}"
    # a bench row added by the PR under test: its gated metrics have no
    # baseline — they must surface as NEW, never fail the wall
    grown = dict(base)
    grown[("serve/spec_decode", "us_per_call")] = 400.0
    grown[("serve/spec_decode", "acceptance_rate")] = 1.0
    entries, failures = diff(base, grown)
    assert not failures, f"fresh-only row failed the wall: {failures}"
    new = {(e[0], e[1]): e[5] for e in entries if e[5] == "NEW"}
    assert new == {("serve/spec_decode", "us_per_call"): "NEW",
                   ("serve/spec_decode", "acceptance_rate"): "NEW"}, \
        f"fresh-only metrics not reported as NEW: {entries}"
    # acceptance_rate present in BOTH files: tracked as INFO, never gated
    moved = dict(grown)
    moved[("serve/spec_decode", "acceptance_rate")] = 0.4  # -60%: still ok
    entries, failures = diff(grown, moved)
    assert not failures, f"INFO metric failed the wall: {failures}"
    assert [e[5] for e in entries
            if e[1] == "acceptance_rate"] == ["INFO"], entries
    # the telemetry row: its tokens_per_tick_* / us_per_call metrics are
    # gated like any serve row — fresh-only it reports NEW, and once in the
    # baseline a past-threshold tokens/tick drop fails the wall
    traced = dict(base)
    traced[("serve/obs_overhead", "tokens_per_tick_on")] = 2.0
    traced[("serve/obs_overhead", "us_per_call")] = 300.0
    entries, failures = diff(base, traced)
    assert not failures, f"fresh obs_overhead row failed the wall: {failures}"
    assert {(e[0], e[1]) for e in entries if e[5] == "NEW"} == {
        ("serve/obs_overhead", "tokens_per_tick_on"),
        ("serve/obs_overhead", "us_per_call")}, entries
    slow_trace = dict(traced)
    slow_trace[("serve/obs_overhead", "tokens_per_tick_on")] = 1.0  # -50%
    _, failures = diff(traced, slow_trace)
    assert [(f[0], f[1]) for f in failures] == \
        [("serve/obs_overhead", "tokens_per_tick_on")], \
        f"obs_overhead tokens/tick drop not caught: {failures}"
    print("self-test passed: 20% drops fail, <=15% noise and reruns pass, "
          "fresh-only rows (incl. serve/obs_overhead) report NEW, "
          "acceptance_rate stays INFO, obs_overhead drops are gated")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", nargs="?",
                    help="committed BENCH_serve.json snapshot")
    ap.add_argument("fresh", nargs="?",
                    help="freshly generated BENCH_serve.json")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="max tolerated fractional regression on gated "
                    "metrics (default 0.15)")
    ap.add_argument("--summary", default=os.environ.get(
        "GITHUB_STEP_SUMMARY", ""),
                    help="append the before/after markdown table here "
                    "(default $GITHUB_STEP_SUMMARY)")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the wall catches a synthetic 20% drop")
    args = ap.parse_args()
    if args.self_test:
        self_test()
        return
    if not (args.baseline and args.fresh):
        ap.error("baseline and fresh paths are required (or --self-test)")
    base, fresh = load(args.baseline), load(args.fresh)
    entries, failures = diff(base, fresh, args.threshold)
    md = render_markdown(entries, failures, args.threshold)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(md + "\n")
    for row, metric, before, after, delta, flag in entries:
        b = "           —" if before is None else f"{before:>12g}"
        dl = "    —" if delta is None else f"{delta:+.1%}"
        mark = f" <-- {flag}" if flag else ""
        print(f"{row:40s} {metric:32s} {b} -> {after:>12g} ({dl}){mark}")
    if not entries:
        print("no gated metrics in common — nothing to compare",
              file=sys.stderr)
    if failures:
        print(f"\n{len(failures)} gated metric(s) regressed past "
              f"{args.threshold:.0%}", file=sys.stderr)
        sys.exit(1)
    print(f"\nregression wall clean ({len(entries)} gated metrics within "
          f"{args.threshold:.0%})")


if __name__ == "__main__":
    main()
