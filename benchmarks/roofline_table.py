"""§Roofline emitter: reads the dry-run JSON cells and prints the three-term
roofline table (single-pod 16x16 mesh per spec)."""
import glob
import json
import os

from repro.analysis.roofline import format_table

RESULTS = os.environ.get("REPRO_DRYRUN_DIR", "results/dryrun")


def load_rows(mesh: str = "16x16", variant: str = "baseline"):
    rows, skips = [], []
    for path in sorted(glob.glob(os.path.join(RESULTS, mesh, variant,
                                              "*.json"))):
        d = json.load(open(path))
        if "skipped" in d:
            skips.append(d)
            continue
        r = d["roofline"]
        r["n_trials"] = int(d["engine"]["n_trials"])
        r["fits"] = d.get("fits_16GB_modeled", d.get("fits_16GB"))
        rows.append(r)
    return rows, skips


def run() -> list[dict]:
    rows, skips = load_rows()
    out = []
    for r in rows:
        out.append({
            "name": f"roofline/{r['arch']}/{r['shape']}",
            "us_per_call": round(max(r["compute_s"], r["memory_s"],
                                     r["collective_s"]) * 1e6, 1),
            "derived": {
                "compute_s": round(r["compute_s"], 4),
                "memory_s": round(r["memory_s"], 4),
                "collective_s": round(r["collective_s"], 4),
                "dominant": r["dominant"],
                "useful_ratio": round(r["useful_ratio"], 4),
                "roofline_fraction": round(r["roofline_fraction"], 4),
            },
        })
    for s in skips:
        out.append({"name": f"roofline/{s['arch']}/{s['shape']}",
                    "us_per_call": 0,
                    "derived": {"skipped": s["skipped"][:80]}})
    return out


def print_pretty(mesh="16x16", variant="baseline"):
    rows, skips = load_rows(mesh, variant)
    print(format_table(rows))
    for s in skips:
        print(f"{s['arch']:26s} {s['shape']:12s} SKIP: {s['skipped'][:70]}")


if __name__ == "__main__":
    import sys
    print_pretty(*(sys.argv[1:] or []))
