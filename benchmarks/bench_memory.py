"""Paper §4.2: BERT-Large per-device memory under model parallelism.

The paper reports a 3× per-device memory reduction sharding BERT-Large over
4×V100. We reproduce the measurement: compile the training step single-device
vs 4-stage model-parallel (fake host devices in a subprocess) and compare
per-device resident bytes from the compiled buffer assignment.
"""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = r"""
import os, json, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp
from repro.configs import PAPER_ARCHS
from repro.core import pipeline as pl
from repro.core.partitioner import plan_stages
from repro.launch.mesh import make_test_mesh
from repro.models.layers import ModelOptions
from repro.optim.adamw import AdamW
from jax.sharding import NamedSharding

def measure(n_stages):
    mesh = make_test_mesh(1, n_stages)
    cfg = PAPER_ARCHS["bert-large"]
    eng = pl.EngineConfig(n_trials=1, n_microbatches=2, microbatch=4,
                          n_stages=n_stages, data_size=1,
                          vocab_parallel=n_stages > 1)
    opts = ModelOptions(param_dtype=jnp.float32, compute_dtype=jnp.float32,
                        remat=True)
    optimizer = AdamW()
    plan = plan_stages(cfg, eng.n_stages)
    pstruct = pl.trial_params_struct(cfg, eng, plan, dtype=jnp.float32,
                                     max_pos=512)
    pspecs = pl.param_pspecs(cfg, eng)
    ps = jax.tree.map(lambda s, sp: jax.ShapeDtypeStruct(
        s.shape, s.dtype, sharding=NamedSharding(mesh, sp)), pstruct, pspecs)
    os_ = jax.tree.map(lambda s, sp: jax.ShapeDtypeStruct(
        s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        optimizer.init_struct(pstruct), optimizer.state_pspecs(pspecs))
    mbg = eng.microbatch
    seq = 384  # SQuAD fine-tune sequence length
    batch = {"tokens": jax.ShapeDtypeStruct((1, 2, mbg, seq), jnp.int32),
             "labels": jax.ShapeDtypeStruct((1, 2, mbg, seq), jnp.int32)}
    fn = pl.make_train_step(cfg, opts, eng, mesh, optimizer, jit=False)
    lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(
        ps, os_, batch, {"lr": jax.ShapeDtypeStruct((1,), jnp.float32),
                         "wd": jax.ShapeDtypeStruct((1,), jnp.float32)},
        jax.ShapeDtypeStruct((), jnp.int32))
    mem = lowered.compile().memory_analysis()
    # memory_analysis is per-device (the module IS the per-device program)
    return (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes)

# single-device measure: whole model on one chip (1-stage mesh)
one = measure(1)
four = measure(4)
print(json.dumps({"single_device_bytes": one, "four_stage_bytes": four,
                  "reduction": one / four}))
"""


def run() -> list[dict]:
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
        capture_output=True, text=True, timeout=560, cwd=ROOT)
    if proc.returncode != 0:
        return [{"name": "bert_memory/error", "us_per_call": -1,
                 "derived": {"stderr": proc.stderr[-500:]}}]
    d = json.loads(proc.stdout.strip().splitlines()[-1])
    return [{
        "name": "bert_memory/per_device_reduction",
        "us_per_call": round(d["reduction"], 3),
        "derived": {
            "single_device_MiB": round(d["single_device_bytes"] / 2**20, 1),
            "four_stage_MiB_per_dev": round(d["four_stage_bytes"] / 2**20, 1),
            "paper_claim": "3x reduction on 4 GPUs",
        },
    }]
