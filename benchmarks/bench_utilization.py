"""Paper Fig. 2: shard vs model vs task parallelism — makespan/utilization
from the discrete-event simulator (K models × S shard-devices)."""
from repro.core import simulator as sim


def run() -> list[dict]:
    rows = []
    for n_shards in (4, 8, 16):
        for r in sim.figure2_table(n_shards=n_shards,
                                   n_models_list=(1, 2, 4, 8, 16)):
            rows.append({
                "name": f"fig2/util/S{n_shards}/K{r['n_models']}",
                "us_per_call": r["shard_makespan"],
                "derived": {
                    "shard_util": round(r["shard_util"], 4),
                    "model_util": round(r["model_util"], 4),
                    "gpipe_util": round(r["gpipe_util"], 4),
                    "task_util": round(r["task_util"], 4),
                    "speedup_vs_model_parallel":
                        round(r["speedup_vs_model_parallel"], 3),
                    "speedup_vs_gpipe": round(r["speedup_vs_gpipe"], 3),
                    "speedup_vs_task_parallel":
                        round(r["speedup_vs_task_parallel"], 3),
                },
            })
    return rows
