"""Paper D2 (throughput): measured wall-time of Hydra shard-parallel
multi-model training vs sequential per-model training on the SAME device
budget — small LM on 8 fake host devices (subprocess; CPU timings are noisy
but the ratio is the signal)."""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import ASSIGNED_ARCHS
from repro.core import pipeline as pl
from repro.core.partitioner import plan_stages
from repro.data.pipeline import TrainBatches
from repro.launch.mesh import make_test_mesh
from repro.models import lm
from repro.models.layers import ModelOptions
from repro.optim.adamw import AdamW

cfg = ASSIGNED_ARCHS["chatglm3-6b"].reduced()
opts = ModelOptions(remat=True)
K, M, MB, SEQ, STEPS = 4, 4, 2, 32, 6
eng = pl.EngineConfig(n_trials=K, n_microbatches=M, microbatch=MB,
                      n_stages=8, data_size=1)
mesh = make_test_mesh(1, 8)
plan = plan_stages(cfg, eng.n_stages)
params = pl.init_trial_params(cfg, eng, plan, jax.random.PRNGKey(0))
optimizer = AdamW()
hp = {"lr": jnp.full((K,), 1e-3), "wd": jnp.zeros((K,))}
data = TrainBatches(cfg, eng, SEQ, seed=0)
batches = [jax.tree.map(jnp.asarray, data.batch_for_step(s))
           for s in range(STEPS)]
data.close()

# snapshot the single-trial baseline params BEFORE the Hydra step donates
params1 = jax.tree.map(lambda x: jnp.array(x[:1]), params)

# --- Hydra: K models pipelined over 8 stages -------------------------------
step_fn = pl.make_train_step(cfg, opts, eng, mesh, optimizer)
p, o = params, optimizer.init(params)
p, o, _ = step_fn(p, o, batches[0], hp, jnp.int32(0))  # compile
jax.block_until_ready(jax.tree.leaves(p)[0])
t0 = time.monotonic()
for s in range(1, STEPS):
    p, o, _ = step_fn(p, o, batches[s], hp, jnp.int32(s))
jax.block_until_ready(jax.tree.leaves(p)[0])
hydra_s = (time.monotonic() - t0) / (STEPS - 1)

# --- baseline: the same K models trained one-at-a-time, model-parallel over
# the same 8 stages (traditional MP: what the paper says people do today) ---
eng1 = pl.EngineConfig(n_trials=1, n_microbatches=M, microbatch=MB,
                       n_stages=8, data_size=1)
step1 = pl.make_train_step(cfg, opts, eng1, mesh, optimizer)
hp1 = {"lr": jnp.full((1,), 1e-3), "wd": jnp.zeros((1,))}
b1 = {k: v[:1] for k, v in batches[0].items()}
p1, o1 = params1, optimizer.init(params1)
p1, o1, _ = step1(p1, o1, b1, hp1, jnp.int32(0))  # compile
jax.block_until_ready(jax.tree.leaves(p1)[0])
t0 = time.monotonic()
for s in range(1, STEPS):
    for k in range(K):  # K sequential model-parallel jobs
        bk = {kk: v[k:k+1] for kk, v in batches[s].items()}
        p1, o1, _ = step1(p1, o1, bk, hp1, jnp.int32(s))
jax.block_until_ready(jax.tree.leaves(p1)[0])
seq_s = (time.monotonic() - t0) / (STEPS - 1)

# each sequential job pays its own fill/drain bubble; Hydra pays one
S = 8
theoretical = K * (M + S - 1) / (K * M + S - 1)
print(json.dumps({"hydra_step_s": hydra_s, "sequential_mp_step_s": seq_s,
                  "speedup": seq_s / hydra_s, "theoretical": theoretical}))
"""


def run() -> list[dict]:
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
        capture_output=True, text=True, timeout=580, cwd=ROOT)
    if proc.returncode != 0:
        return [{"name": "pipeline_throughput/error", "us_per_call": -1,
                 "derived": {"stderr": proc.stderr[-500:]}}]
    d = json.loads(proc.stdout.strip().splitlines()[-1])
    return [{
        "name": "pipeline_throughput/hydra_vs_sequential_mp",
        "us_per_call": round(d["hydra_step_s"] * 1e6, 1),
        "derived": {
            "sequential_mp_us": round(d["sequential_mp_step_s"] * 1e6, 1),
            "measured_speedup": round(d["speedup"], 3),
            "theoretical_speedup": round(d["theoretical"], 3),
        },
    }]
