"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  fig2/*        paper Figure 2 (simulator: shard vs model vs task parallel)
  bert_memory/* paper §4.2 (per-device memory reduction, BERT-Large, 4-way)
  pipeline_throughput/* paper D2 (measured Hydra vs sequential MP wall time)
  exactness/*   paper D3 (pipelined == sequential training)
  serve/*       continuous vs static, paged vs dense, K-arch gang vs
                sequential single-arch engines, admission policies,
                telemetry overhead (obs_overhead: tracing-off parity +
                tracing-on < 5% wall cost; writes the traced run's
                Perfetto/event/metrics artifacts into benchmarks/results/)
  roofline/*    §Roofline terms per (arch × shape) from the dry-run artifacts

``--json PATH`` additionally writes the rows as a JSON list (the nightly CI
job uploads these as workflow artifacts for trend tracking) and, whenever
any ``serve/*`` rows ran, a stable flat ``BENCH_serve.json`` at the repo
root — one ``{row, metric, value, units}`` record per numeric result, so
the serving perf trajectory diffs cleanly across PRs.

Exit status: non-zero when any section raises or reports a failed row
(``us_per_call`` < 0 — the per-bench error convention), so CI smoke jobs
catch regressions instead of reading a green harness over red rows.
"""
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# units for the flat BENCH_serve.json schema, keyed by metric-name substring
# (first match wins; unmatched numeric metrics are dimensionless counts)
_UNITS = (
    ("us_per_call", "us/call"),
    ("tokens_per_s", "tok/s"),
    ("tokens_per_tick", "tok/tick"),
    ("ticks_per_token", "ticks/token"),
    ("acceptance_rate", "fraction"),
    ("ttft", "ticks"),
    ("tpot", "ticks/token"),
    ("wall_s", "s"),
    ("occupancy", "fraction"),
    ("frac", "fraction"),
    ("_mb", "MiB"),
    ("ticks", "ticks"),
    ("calls", "calls"),
    ("tokens", "tokens"),
    ("blocks", "blocks"),
)


def _units_for(metric: str) -> str:
    for sub, unit in _UNITS:
        if sub in metric:
            return unit
    return "count"


def write_bench_serve(rows, path) -> bool:
    """Flatten the serve/* rows into the stable {row, metric, value, units}
    schema tracked across PRs. Returns False — leaving any existing file
    untouched — when no serve rows ran OR any serve row failed (us_per_call
    < 0), so a crashed or gate-failing run never clobbers the last good
    trajectory with error rows.
    """
    serve_rows = [r for r in rows if r["name"].startswith("serve/")]
    if any(r["us_per_call"] < 0 for r in serve_rows):
        return False
    recs = []
    for r in serve_rows:
        recs.append({"row": r["name"], "metric": "us_per_call",
                     "value": r["us_per_call"], "units": "us/call"})
        for k in sorted(r["derived"]):
            v = r["derived"][k]
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            recs.append({"row": r["name"], "metric": k, "value": v,
                         "units": _units_for(k)})
    if not recs:
        return False
    with open(path, "w") as f:
        json.dump(recs, f, indent=2)
        f.write("\n")
    return True


def main() -> None:
    from benchmarks import (bench_exactness, bench_memory, bench_pipeline,
                            bench_serve, bench_utilization, roofline_table)
    argv = list(sys.argv[1:])
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        try:
            json_path = argv[i + 1]
        except IndexError:
            sys.exit("--json needs an output path")
        del argv[i:i + 2]
    only = argv[0] if argv else None
    all_benches = {
        "utilization": bench_utilization.run,
        "memory": bench_memory.run,
        "pipeline": bench_pipeline.run,
        "exactness": bench_exactness.run,
        "serve": bench_serve.run,
        "roofline": roofline_table.run,
    }
    if only and only not in all_benches:
        sys.exit(f"unknown benchmark section {only!r} "
                 f"(choose from: {', '.join(all_benches)})")
    failed = []
    all_rows = []
    print("name,us_per_call,derived")
    for name, fn in all_benches.items():
        if only and only != name:
            continue
        try:
            rows = fn()
        except Exception as e:  # report, keep harness running
            rows = [{"name": f"{name}/harness_error", "us_per_call": -1,
                     "derived": {"error": repr(e)[:200]}}]
        for r in rows:
            if r["us_per_call"] < 0:
                failed.append(r["name"])
            all_rows.append(r)
            print(f"{r['name']},{r['us_per_call']},"
                  f"\"{json.dumps(r['derived'])}\"")
    if json_path:
        # the raw --json dump is diagnostic and always written — failed rows
        # included — so CI artifacts capture exactly what ran
        d = os.path.dirname(json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(all_rows, f, indent=2)
        # the repo-root trajectory is the committed baseline future runs diff
        # against: refresh it only when EVERY row passed (a harness error in
        # any section means this run is not a trustworthy reference point)
        if failed:
            print("skipping BENCH_serve.json: failed rows present",
                  file=sys.stderr)
        elif write_bench_serve(all_rows,
                               os.path.join(ROOT, "BENCH_serve.json")):
            print(f"wrote BENCH_serve.json ({len(all_rows)} rows scanned)",
                  file=sys.stderr)
    if failed:
        print(f"FAILED sections: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
