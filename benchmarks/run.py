"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  fig2/*        paper Figure 2 (simulator: shard vs model vs task parallel)
  bert_memory/* paper §4.2 (per-device memory reduction, BERT-Large, 4-way)
  pipeline_throughput/* paper D2 (measured Hydra vs sequential MP wall time)
  exactness/*   paper D3 (pipelined == sequential training)
  serve/*       continuous vs static batching (tok/s + slot occupancy)
  roofline/*    §Roofline terms per (arch × shape) from the dry-run artifacts
"""
import json
import sys


def main() -> None:
    sections = []
    from benchmarks import (bench_exactness, bench_memory, bench_pipeline,
                            bench_serve, bench_utilization, roofline_table)
    only = sys.argv[1] if len(sys.argv) > 1 else None
    all_benches = {
        "utilization": bench_utilization.run,
        "memory": bench_memory.run,
        "pipeline": bench_pipeline.run,
        "exactness": bench_exactness.run,
        "serve": bench_serve.run,
        "roofline": roofline_table.run,
    }
    print("name,us_per_call,derived")
    for name, fn in all_benches.items():
        if only and only != name:
            continue
        try:
            rows = fn()
        except Exception as e:  # report, keep harness running
            rows = [{"name": f"{name}/harness_error", "us_per_call": -1,
                     "derived": {"error": repr(e)[:200]}}]
        for r in rows:
            print(f"{r['name']},{r['us_per_call']},"
                  f"\"{json.dumps(r['derived'])}\"")


if __name__ == "__main__":
    main()
