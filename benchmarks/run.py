"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  fig2/*        paper Figure 2 (simulator: shard vs model vs task parallel)
  bert_memory/* paper §4.2 (per-device memory reduction, BERT-Large, 4-way)
  pipeline_throughput/* paper D2 (measured Hydra vs sequential MP wall time)
  exactness/*   paper D3 (pipelined == sequential training)
  serve/*       continuous vs static, paged vs dense, K-arch gang vs
                sequential single-arch engines, admission policies
  roofline/*    §Roofline terms per (arch × shape) from the dry-run artifacts

``--json PATH`` additionally writes the rows as a JSON list (the nightly CI
job uploads these as workflow artifacts for trend tracking).

Exit status: non-zero when any section raises or reports a failed row
(``us_per_call`` < 0 — the per-bench error convention), so CI smoke jobs
catch regressions instead of reading a green harness over red rows.
"""
import json
import os
import sys


def main() -> None:
    from benchmarks import (bench_exactness, bench_memory, bench_pipeline,
                            bench_serve, bench_utilization, roofline_table)
    argv = list(sys.argv[1:])
    json_path = None
    if "--json" in argv:
        i = argv.index("--json")
        try:
            json_path = argv[i + 1]
        except IndexError:
            sys.exit("--json needs an output path")
        del argv[i:i + 2]
    only = argv[0] if argv else None
    all_benches = {
        "utilization": bench_utilization.run,
        "memory": bench_memory.run,
        "pipeline": bench_pipeline.run,
        "exactness": bench_exactness.run,
        "serve": bench_serve.run,
        "roofline": roofline_table.run,
    }
    if only and only not in all_benches:
        sys.exit(f"unknown benchmark section {only!r} "
                 f"(choose from: {', '.join(all_benches)})")
    failed = []
    all_rows = []
    print("name,us_per_call,derived")
    for name, fn in all_benches.items():
        if only and only != name:
            continue
        try:
            rows = fn()
        except Exception as e:  # report, keep harness running
            rows = [{"name": f"{name}/harness_error", "us_per_call": -1,
                     "derived": {"error": repr(e)[:200]}}]
        for r in rows:
            if r["us_per_call"] < 0:
                failed.append(r["name"])
            all_rows.append(r)
            print(f"{r['name']},{r['us_per_call']},"
                  f"\"{json.dumps(r['derived'])}\"")
    if json_path:
        d = os.path.dirname(json_path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(json_path, "w") as f:
            json.dump(all_rows, f, indent=2)
    if failed:
        print(f"FAILED sections: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
