"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV:
  fig2/*        paper Figure 2 (simulator: shard vs model vs task parallel)
  bert_memory/* paper §4.2 (per-device memory reduction, BERT-Large, 4-way)
  pipeline_throughput/* paper D2 (measured Hydra vs sequential MP wall time)
  exactness/*   paper D3 (pipelined == sequential training)
  serve/*       continuous vs static + paged vs dense (capacity, occupancy)
  roofline/*    §Roofline terms per (arch × shape) from the dry-run artifacts

Exit status: non-zero when any section raises or reports a failed row
(``us_per_call`` < 0 — the per-bench error convention), so CI smoke jobs
catch regressions instead of reading a green harness over red rows.
"""
import json
import sys


def main() -> None:
    from benchmarks import (bench_exactness, bench_memory, bench_pipeline,
                            bench_serve, bench_utilization, roofline_table)
    only = sys.argv[1] if len(sys.argv) > 1 else None
    all_benches = {
        "utilization": bench_utilization.run,
        "memory": bench_memory.run,
        "pipeline": bench_pipeline.run,
        "exactness": bench_exactness.run,
        "serve": bench_serve.run,
        "roofline": roofline_table.run,
    }
    if only and only not in all_benches:
        sys.exit(f"unknown benchmark section {only!r} "
                 f"(choose from: {', '.join(all_benches)})")
    failed = []
    print("name,us_per_call,derived")
    for name, fn in all_benches.items():
        if only and only != name:
            continue
        try:
            rows = fn()
        except Exception as e:  # report, keep harness running
            rows = [{"name": f"{name}/harness_error", "us_per_call": -1,
                     "derived": {"error": repr(e)[:200]}}]
        for r in rows:
            if r["us_per_call"] < 0:
                failed.append(r["name"])
            print(f"{r['name']},{r['us_per_call']},"
                  f"\"{json.dumps(r['derived'])}\"")
    if failed:
        print(f"FAILED sections: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
