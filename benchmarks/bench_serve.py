"""Serving comparisons under a Poisson arrival trace (subprocess, 8 fake
host devices).

Three claims under test:

* ``serve/continuous_vs_static`` — Hydra's slot-filling insight applied to
  serving: recycling a finished request's pipeline slot immediately keeps
  occupancy near 1 where the lockstep batch decays as it drains. Gated on
  tokens per engine tick (the deterministic scheduling unit), NOT wall
  tok/s — wall time folds in jit compiles and host jitter, which the
  static path's fewer distinct shapes flatter.
* ``serve/paged_vs_dense`` — paging the KV-cache (shared block pool +
  per-request block tables) lets ``plan_serve_capacity`` admit by *expected*
  request length instead of reserving a worst-case ``max_seq`` strip per
  cell, so the same HBM budget admits strictly more concurrent requests —
  with per-request greedy tokens bit-identical to the dense path.
* ``serve/multiarch_gang_vs_sequential`` — the co-serving tentpole: one K=2
  gang routing a mixed request stream across its trial rows beats running
  the two single-arch engines back to back at the same HBM budget on
  aggregate tok/s (one compiled program, shared ticks, no second drain
  tail), with greedy tokens bit-identical per request.
* ``serve/prefix_cache`` — the radix prefix cache: on a trace where 50% of
  requests share a 12-token prompt prefix, cross-request KV sharing must
  cut prefill slot-ticks — (cell, round) pairs spent prefilling, i.e. each
  request's prefill-wave count summed — by >= 30% and lower mean TTFT
  versus the same paged engine without the cache, at equal HBM (identical
  pool) with greedy tokens bit-identical.

* ``serve/overcommit_retract`` — preemptive overcommit: on a bursty trace
  through a pool that fits only a fraction of the burst, admitting past the
  pool (overcommit 1.5, retraction + host swap-restore) must sustain higher
  tokens/tick than the preemption-free overcommit-1.0 schedule, complete every
  request (no deadlock), and keep greedy tokens bit-identical.
* ``serve/host_prefix_spill`` — the host-offloaded prefix cache: at equal
  HBM (identical pool), spilling evicted radix nodes to a host tier instead
  of destroying them must raise the effective prefix-hit token count (hits
  on host-resident nodes swap back in) with 0 token mismatches.
* ``serve/paged_kernel_vs_gather`` — the block-table-native attention path
  (``--paged-kernel``): on a long-context-provisioned engine (max_seq far
  above the actual request lengths) the kernel path — trimmed block tables,
  attention straight from the pool, O(live) work per call — must beat the
  gather path's O(max_seq) materialization on tok/s at the longest tested
  sequence length, with greedy tokens bit-identical to both the gather path
  and the single-device oracle. Both engines are timed on a second run with
  warm jit caches (the kernel path compiles one step per power-of-two table
  bucket; compile time is excluded from the comparison for both).
* ``serve/spec_decode`` — gang-speculative decoding: a drafter trial row
  autoregressively proposes gamma tokens and the paired target row scores
  them in ONE ragged verify call (per-position argmax). With a perfect
  drafter the target must spend >= 1.3x fewer of its own ticks per output
  token than the target-only engine; greedy tokens must be bit-identical
  to the baseline and the single-device oracle across dense, paged-gather,
  paged-kernel and a rejecting mixed-drafter run; rejection must roll
  blocks back and the pool must drain to fully free.
* ``serve/fused_admission`` — fused mixed-tick admission: folding each
  round's per-chunk-length prefill waves and the decode step into ONE
  pipeline program (per-row ragged q-lengths: chunk width prefilling, 1
  decoding, 0 idle) must issue strictly fewer pipeline calls than the
  split schedule on an admission-heavy trace, with no drop in decode
  occupancy and greedy tokens + tick latencies bit-identical to split
  (and tokens matching the single-device oracle).

``serve/admission_policies`` additionally reports p95 TTFT for the
fcfs / sjf / deadline batcher policies on one shared Poisson trace.
``BENCH_SERVE_SLOW=1`` (nightly) scales the bursty/spill traces up.

``us_per_call`` is wall seconds per pipeline call (``1e6 * wall_s /
calls`` from the row's primary engine run) — NOT a per-token number;
per-token rates live in the ``tokens_per_s_*`` derived entries.
"""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = r"""
import dataclasses, json, os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ASSIGNED_ARCHS
from repro.core import pipeline as pl
from repro.core import scheduler as sched
from repro.core.partitioner import plan_stages
from repro.launch.mesh import make_test_mesh
from repro.models.layers import ModelOptions
from repro.serve import Request, ServeEngine, poisson_trace, static_serve

cfg = ASSIGNED_ARCHS["chatglm3-6b"].reduced()
opts = ModelOptions()
mesh = make_test_mesh(1, 4)

def clone(reqs):
    return [r.clone() for r in reqs]

# --- paged vs dense at the SAME HBM budget --------------------------------
MAX_SEQ, BLOCK = 20, 4
base = pl.EngineConfig(n_trials=1, n_microbatches=1, microbatch=2,
                       n_stages=4, data_size=1, max_seq=MAX_SEQ,
                       cache_dtype=jnp.float32, prefill_chunks=2)
# budget = fixed fwd cost + two dense slots' worth of cache strips
est = sched.per_chip_bytes(cfg, base, MAX_SEQ, train=False)
strip = base.microbatch * MAX_SEQ * sched.kv_token_bytes_per_chip(cfg, base)
budget = est.params_bytes + est.act_bytes + 2 * strip
dense_eng = sched.plan_serve_capacity(cfg, base, MAX_SEQ, hbm_bytes=budget,
                                      budget_fraction=1.0, max_slots=8)
paged_eng = sched.plan_serve_capacity(cfg, base, MAX_SEQ, paged=True,
                                      expected_seq=10, block_size=BLOCK,
                                      hbm_bytes=budget, budget_fraction=1.0,
                                      max_slots=8)
plan = plan_stages(cfg, base.n_stages)
params = pl.init_trial_params(cfg, base, plan, jax.random.PRNGKey(0),
                              max_pos=MAX_SEQ)
trace = poisson_trace(16, rate=3.0, vocab=cfg.vocab_size,
                      prompt_lens=(8, 12), gen_lens=(2, 4), seed=0)
e_dense = ServeEngine(cfg, dense_eng, mesh, params, opts)
comp_dense = e_dense.run(clone(trace))
e_paged = ServeEngine(cfg, paged_eng, mesh, params, opts)
comp_paged = e_paged.run(clone(trace))
paged_mism = sum(a.tokens != b.tokens
                 for a, b in zip(comp_dense, comp_paged))
pvd = {
    "budget_mb": round(budget / 2**20, 2),
    "cells_dense": e_dense.batcher.n_cells,
    "cells_paged": e_paged.batcher.n_cells,
    "n_blocks": paged_eng.n_blocks, "block_size": paged_eng.block_size,
    "token_mismatches": paged_mism,
    "dense": e_dense.stats.summary(), "paged": e_paged.stats.summary(),
}

# --- one K=2 gang vs two sequential single-arch engines, equal HBM --------
# budget: two variants' params + a few dense strips; the gang splits it
# across its trial rows, each sequential engine may use ALL of it (it runs
# alone) — the honest equal-peak-HBM comparison.
gang_budget = 2 * est.params_bytes + est.act_bytes + 4 * strip
gang_eng = sched.plan_serve_capacity(cfg, base, MAX_SEQ,
                                     mix=[(1.0, 10), (1.0, 10)],
                                     hbm_bytes=gang_budget,
                                     budget_fraction=1.0, max_slots=4)
solo_eng = sched.plan_serve_capacity(cfg, base, MAX_SEQ,
                                     hbm_bytes=gang_budget,
                                     budget_fraction=1.0, max_slots=4)
params2 = pl.init_trial_params(cfg, gang_eng, plan, jax.random.PRNGKey(0),
                               max_pos=MAX_SEQ)
mixed = poisson_trace(16, rate=3.0, vocab=cfg.vocab_size,
                      prompt_lens=(8, 12), gen_lens=(2, 4), seed=1,
                      n_arches=2)
e_gang = ServeEngine(cfg, gang_eng, mesh, params2, opts)
comp_gang = e_gang.run(clone(mixed))
solo_comp, solo_wall, solo_tokens = {}, 0.0, 0
for k in range(2):
    params_k = jax.tree.map(lambda x: x[k:k + 1], params2)
    mine = clone([r for r in mixed if r.arch == k])
    for r in mine:
        r.arch = 0  # the solo engine has one trial row
    e_solo = ServeEngine(cfg, dataclasses.replace(solo_eng, n_trials=1),
                         mesh, params_k, opts)
    for c in e_solo.run(mine):
        solo_comp[c.rid] = c
    solo_wall += e_solo.stats.wall_s
    solo_tokens += e_solo.stats.tokens_generated
gang_mism = sum(c.tokens != solo_comp[c.rid].tokens for c in comp_gang)
gs = e_gang.stats
mvs = {
    "budget_mb": round(gang_budget / 2**20, 2),
    "cells_gang": e_gang.batcher.n_cells,
    "cells_solo_each": solo_eng.n_microbatches * solo_eng.microbatch,
    "token_mismatches": gang_mism,
    "gang": gs.summary(),
    "tokens_per_s_gang": round(gs.tokens_per_s, 2),
    "tokens_per_s_sequential": round(
        solo_tokens / solo_wall if solo_wall > 0 else 0.0, 2),
    "wall_s_gang": round(gs.wall_s, 2),
    "wall_s_sequential": round(solo_wall, 2),
}

# --- admission policies: p95 TTFT on one shared trace ---------------------
ptrace = poisson_trace(14, rate=4.0, vocab=cfg.vocab_size,
                       prompt_lens=(6, 12), gen_lens=(2, 4), seed=2,
                       deadline_slack=3.0)
pol_eng = dataclasses.replace(base, n_microbatches=2)
pol = {}
for policy in ("fcfs", "sjf", "deadline"):
    e_pol = ServeEngine(cfg, pol_eng, mesh, params, opts, policy=policy)
    e_pol.run(clone(ptrace))
    s = e_pol.stats.summary()
    pol[policy] = {"ttft_p95": s.get("ttft_p95", -1.0),
                   "ttft_p50": s.get("ttft_p50", -1.0),
                   "us_per_call": round(
                       1e6 * s["wall_s"] / max(s["calls"], 1), 1)}

# --- radix prefix cache: 50%-shared-prefix trace, cache on vs off ---------
# equal HBM by construction: the cache-on and cache-off runs use the SAME
# paged engine config (same pool); only the radix tree + CoW forks differ
PC_MAX, PC_BLOCK = 20, 4
pc_eng = dataclasses.replace(base, n_microbatches=2, max_seq=PC_MAX,
                             prefill_chunks=4, paged=True,
                             block_size=PC_BLOCK, n_blocks=40)
params_pc = pl.init_trial_params(cfg, pc_eng, plan, jax.random.PRNGKey(0),
                                 max_pos=PC_MAX)
rng_pc = np.random.default_rng(7)
shared = rng_pc.integers(0, cfg.vocab_size, (12,)).astype(np.int32)


def shared_prompt():
    sfx = rng_pc.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
    return np.concatenate([shared, sfx])


# a warm-up sharer at t=0 seeds the tree on completion; the measured stream
# arrives later, alternating sharers (50%) and cold 16-token prompts
pc_reqs = [Request(0, shared_prompt(), 4, arrival=0.0)]
t_pc = 40.0
for i in range(1, 17):
    t_pc += float(rng_pc.exponential(1.0))
    prompt = (shared_prompt() if i % 2 else
              rng_pc.integers(0, cfg.vocab_size, (16,)).astype(np.int32))
    pc_reqs.append(Request(i, prompt, 4, arrival=t_pc))
e_nc = ServeEngine(cfg, pc_eng, mesh, params_pc, opts)
comp_nc = e_nc.run(clone(pc_reqs))
e_pc = ServeEngine(cfg, pc_eng, mesh, params_pc, opts, prefix_cache=True)
comp_pc = e_pc.run(clone(pc_reqs))
spc, snc = e_pc.stats.summary(), e_nc.stats.summary()
pfx = {
    "token_mismatches": sum(a.tokens != b.tokens
                            for a, b in zip(comp_nc, comp_pc)),
    "pool": f"{pc_eng.n_blocks}x{pc_eng.block_size}",
    "prefill_slot_ticks_cache": spc["prefill_slot_ticks"],
    "prefill_slot_ticks_nocache": snc["prefill_slot_ticks"],
    "prefill_calls_cache": spc["prefill_calls"],
    "prefill_calls_nocache": snc["prefill_calls"],
    "ttft_mean_cache": round(float(np.mean(e_pc.stats.ttft_samples)), 2),
    "ttft_mean_nocache": round(float(np.mean(e_nc.stats.ttft_samples)), 2),
    "prefix_hits": spc["prefix_hits"],
    "prefix_hit_tokens": spc["prefix_hit_tokens"],
    "prefix_evictions": spc["prefix_evictions"],
    "cow_forks": spc["cow_forks"],
    "cache": spc, "nocache": snc,
}

# --- preemptive overcommit: bursty trace, retraction vs preemption-free ---
SLOW = os.environ.get("BENCH_SERVE_SLOW") == "1"
oc_eng = dataclasses.replace(base, n_microbatches=2, paged=True,
                             block_size=4, n_blocks=6)
rng_oc = np.random.default_rng(11)
oc_shapes = [(11, 5), (10, 6), (9, 4), (11, 6), (10, 5), (9, 6)] * (4 if SLOW
                                                                    else 1)
oc_reqs = [Request(i, rng_oc.integers(0, cfg.vocab_size,
                                      (p,)).astype(np.int32), g, arrival=0.0)
           for i, (p, g) in enumerate(oc_shapes)]
e_oc1 = ServeEngine(cfg, oc_eng, mesh, params, opts, overcommit=1.0)
comp_oc1 = e_oc1.run(clone(oc_reqs), max_ticks=20_000)
e_oc = ServeEngine(cfg, oc_eng, mesh, params, opts, overcommit=1.5,
                   host_blocks=16)
comp_oc = e_oc.run(clone(oc_reqs), max_ticks=20_000)
soc1, soc = e_oc1.stats.summary(), e_oc.stats.summary()
ovc = {
    "n_requests": len(oc_reqs), "pool": f"{oc_eng.n_blocks}x4",
    "token_mismatches": sum(a.tokens != b.tokens
                            for a, b in zip(comp_oc1, comp_oc)),
    "completed_oc10": len(comp_oc1), "completed_oc15": len(comp_oc),
    "retractions": soc["retractions"], "restored": soc["restored"],
    "swap_out_blocks": soc["swap_out_blocks"],
    "swap_in_blocks": soc["swap_in_blocks"],
    "oc10": soc1, "oc15": soc,
}

# --- host-offloaded prefix cache: spill tier on vs off at equal HBM -------
sp_eng = dataclasses.replace(base, n_microbatches=2, paged=True,
                             block_size=4, n_blocks=6, prefill_chunks=4)
rng_sp = np.random.default_rng(13)
sp_shared = rng_sp.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
sp_sufs = [rng_sp.integers(0, cfg.vocab_size, (4,)).astype(np.int32)
           for _ in range(3)]
n_sp = 18 if SLOW else 8
sp_reqs = [Request(i, np.concatenate([sp_shared, sp_sufs[i % 3]]),
                   4 + i % 3, arrival=2.0 * i) for i in range(n_sp)]
e_nosp = ServeEngine(cfg, sp_eng, mesh, params, opts, prefix_cache=True,
                     host_blocks=0)
comp_nosp = e_nosp.run(clone(sp_reqs), max_ticks=20_000)
e_sp = ServeEngine(cfg, sp_eng, mesh, params, opts, prefix_cache=True,
                   host_blocks=16)
comp_sp = e_sp.run(clone(sp_reqs), max_ticks=20_000)
ssp, snosp = e_sp.stats.summary(), e_nosp.stats.summary()
spl = {
    "n_requests": n_sp, "pool": f"{sp_eng.n_blocks}x4",
    "token_mismatches": sum(a.tokens != b.tokens
                            for a, b in zip(comp_nosp, comp_sp)),
    "host": ssp, "nohost": snosp,
}

# --- paged kernel vs gather: attend straight from the block pool ----------
# long-context provisioning: every cell is admitted against max_seq
# capacity, requests actually use far less. The gather path pays
# O(max_seq) per attention call regardless; the kernel path (trimmed
# tables + block-table-native attention) pays O(live).
from repro.models import lm
from repro.serve.engine import ServeStats
PK_MAX, PK_BLOCK, PK_GEN = 2048, 16, 8
pk_eng = dataclasses.replace(base, n_microbatches=2, max_seq=PK_MAX,
                             paged=True, block_size=PK_BLOCK, n_blocks=96,
                             prefill_chunks=2)
params_pk = pl.init_trial_params(cfg, pk_eng, plan, jax.random.PRNGKey(0),
                                 max_pos=PK_MAX)
rng_pk = np.random.default_rng(17)
pk_seqs = [64, 160, 320]
pk_traces = {
    S: [Request(100 * S + i,
                rng_pk.integers(0, cfg.vocab_size,
                                (S - PK_GEN,)).astype(np.int32),
                PK_GEN, arrival=0.0) for i in range(4)]
    for S in pk_seqs}


def serve_oracle(req, params_o, max_pos):
    p1 = jax.tree.map(lambda x: x[0], params_o)
    vpad = p1["embed"]["tok"].shape[0]
    if vpad != cfg.vocab_size:
        p1["embed"]["tok"] = p1["embed"]["tok"][:cfg.vocab_size]
        if "head" in p1:
            p1["head"] = p1["head"][:, :cfg.vocab_size]
    n_stack = jax.tree.leaves(p1["layers"])[0].shape[0]
    cache = lm.init_cache(cfg, 1, max_pos, cache_dtype=jnp.float32,
                          n_layers=n_stack)
    logits, cache, _ = lm.forward(cfg, opts, p1,
                                  {"tokens": jnp.asarray(req.prompt[None])},
                                  mode="prefill", cache=cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    for t in range(req.max_new_tokens - 1):
        logits, cache, _ = lm.forward(
            cfg, opts, p1, {"tokens": jnp.asarray([[toks[-1]]], jnp.int32)},
            mode="decode", cache=cache,
            kv_offset=jnp.asarray([req.prompt_len + t], jnp.int32))
        toks.append(int(jnp.argmax(logits[0, 0])))
    return toks


# one engine per path, reused across sequence lengths so each power-of-two
# table bucket compiles once; run 1 warms the jit caches, run 2 is timed
e_pk = {"gather": ServeEngine(cfg, pk_eng, mesh, params_pk, opts),
        "kernel": ServeEngine(cfg, pk_eng, mesh, params_pk,
                              ModelOptions(use_paged_kernel=True))}
pk = {"max_seq": PK_MAX, "block_size": PK_BLOCK, "seqs": {}}
for S in pk_seqs:
    res = {}
    for name, e in e_pk.items():
        e.run(clone(pk_traces[S]))
        e.stats, e.completions = ServeStats(), []
        comps = e.run(clone(pk_traces[S]))
        res[name] = (e.stats.summary(), {c.rid: c.tokens for c in comps})
    entry = {
        "gather": res["gather"][0], "kernel": res["kernel"][0],
        "token_mismatches": sum(res["gather"][1][r] != res["kernel"][1][r]
                                for r in res["gather"][1]),
    }
    if S == max(pk_seqs):
        entry["oracle_mismatches"] = sum(
            serve_oracle(r, params_pk, PK_MAX) != res["kernel"][1][r.rid]
            for r in pk_traces[S])
    pk["seqs"][str(S)] = entry

# --- fused mixed-tick admission: one pipeline program per round -----------
# admission-heavy trace: a fast Poisson stream keeps several cells mid-
# prefill at staggered chunk widths while others decode — the split
# schedule pays one append call per chunk-length group plus a decode call
# per round, the fused schedule one mixed call (plus a tail decode on
# rounds where a prompt completes)
fa_eng = dataclasses.replace(base, n_microbatches=2, paged=True,
                             block_size=BLOCK, n_blocks=40)
fa_reqs = poisson_trace(16, rate=4.0, vocab=cfg.vocab_size,
                        prompt_lens=(6, 12), gen_lens=(2, 5), seed=23)
e_split_fa = ServeEngine(cfg, fa_eng, mesh, params, opts)
comp_split_fa = e_split_fa.run(clone(fa_reqs))
e_fused_fa = ServeEngine(cfg, fa_eng, mesh, params, opts, fused=True)
comp_fused_fa = e_fused_fa.run(clone(fa_reqs))
fa = {
    "n_requests": len(fa_reqs),
    "token_mismatches": sum(a.tokens != b.tokens for a, b in
                            zip(comp_split_fa, comp_fused_fa)),
    "latency_mismatches": sum(
        a.ttft_ticks != b.ttft_ticks or a.finished_tick != b.finished_tick
        for a, b in zip(comp_split_fa, comp_fused_fa)),
    "oracle_mismatches": sum(
        serve_oracle(r, params, MAX_SEQ) != comp_fused_fa[i].tokens
        for i, r in enumerate(fa_reqs[:6])),
    "fused": e_fused_fa.stats.summary(),
    "split": e_split_fa.stats.summary(),
}

# --- gang-speculative decoding: drafter rows draft, big rows verify -------
# equal target capacity: the baseline is the SAME grid minus the drafter
# trial row. The headline metric is target-row ticks per output token —
# prefill + verify calls for the spec engine vs ALL calls for the baseline
# (drafter ticks ride on trial rows the baseline doesn't have; cheap-drafter
# cost asymmetry is the heterogeneous-arch ROADMAP follow-up).
SPEC_GAMMA = 3
sd_base = dataclasses.replace(base, n_trials=2, n_microbatches=2)
sd_paged = dataclasses.replace(sd_base, paged=True, block_size=BLOCK,
                               n_blocks=40)
params_sd = pl.init_trial_params(cfg, sd_base, plan, jax.random.PRNGKey(0),
                                 max_pos=MAX_SEQ)
# perfect drafter (row 0's weights mirrored) pins acceptance at 1.0 — the
# upper bound; the mixed run keeps row 1's own init (near-zero acceptance)
# to exercise verify rejection + block rollback on every round
params_perf = jax.tree.map(lambda x: jnp.concatenate([x[:1], x[:1]], 0),
                           params_sd)
params_tgt = jax.tree.map(lambda x: x[:1], params_sd)
tgt_dense = dataclasses.replace(sd_base, n_trials=1)
tgt_paged = dataclasses.replace(sd_paged, n_trials=1)
rng_sd = np.random.default_rng(29)
sd_shapes = [(8, 12), (12, 8), (8, 9), (12, 6), (8, 12), (12, 8)]
sd_reqs = [Request(i, rng_sd.integers(0, cfg.vocab_size,
                                      (p,)).astype(np.int32),
                   g, arrival=1.0 * i) for i, (p, g) in enumerate(sd_shapes)]


def run_sd(engcfg, ps, o=opts, **kw):
    e = ServeEngine(cfg, engcfg, mesh, ps, o, **kw)
    comps = e.run(clone(sd_reqs))
    return e, {c.rid: c.tokens for c in comps}


e_bd, toks_ref = run_sd(tgt_dense, params_tgt)
e_bp, toks_bp = run_sd(tgt_paged, params_tgt)
e_sd, toks_sd = run_sd(sd_base, params_perf, spec_gamma=SPEC_GAMMA)
e_sp, toks_sp = run_sd(sd_paged, params_perf, spec_gamma=SPEC_GAMMA)
e_sk, toks_sk = run_sd(sd_paged, params_perf,
                       o=ModelOptions(use_paged_kernel=True),
                       spec_gamma=SPEC_GAMMA)
e_sm, toks_sm = run_sd(sd_paged, params_sd, spec_gamma=SPEC_GAMMA)


def tpt_target(e, spec=False):
    # target-row pipeline ticks per output token
    s = e.stats
    tgt = (s.prefill_calls + e.spec_stats.verify_calls) if spec else s.calls
    return round(tgt / max(s.tokens_generated, 1), 4)


sd = {
    "n_requests": len(sd_reqs), "gamma": SPEC_GAMMA,
    "token_mismatches": sum(
        toks_ref[r] != t[r]
        for t in (toks_bp, toks_sd, toks_sp, toks_sk, toks_sm)
        for r in toks_ref),
    "oracle_mismatches": sum(
        serve_oracle(r, params_tgt, MAX_SEQ) != toks_sp[r.rid]
        for r in sd_reqs[:4]),
    "ticks_per_token_base_dense": tpt_target(e_bd),
    "ticks_per_token_spec_dense": tpt_target(e_sd, True),
    "ticks_per_token_base_paged": tpt_target(e_bp),
    "ticks_per_token_spec_paged": tpt_target(e_sp, True),
    "rollback_blocks_mixed": e_sm.spec_stats.rollback_blocks,
    "all_free_after": int(e_sm.allocator.all_free()
                          and e_sp.allocator.all_free()
                          and e_sk.allocator.all_free()),
    "perfect": e_sp.spec_stats.summary(),
    "mixed": e_sm.spec_stats.summary(),
    "spec": e_sp.stats.summary(), "base": e_bp.stats.summary(),
}

# --- observability overhead: tracing off vs on, same engine + trace -------
# the telemetry layer's contract: tracing OFF must be free (greedy tokens,
# tick count and tokens/tick bit-identical to the untraced engine — the
# disabled path takes one `enabled` branch per hot site), tracing ON must
# cost < 5% wall tok/s. The trace is long enough (~60+ engine ticks) that
# 5% is measurable above host jitter, timed runs interleave off/on so
# machine-state drift hits both variants equally, and wall is min-of-5
# warm runs per variant; the trace must pass the span validator.
from repro.obs import (Tracer, TraceInvariantError, validate_spans,
                       write_events, write_metrics, write_perfetto)
ob_eng = dataclasses.replace(base, n_microbatches=2, paged=True,
                             block_size=BLOCK, n_blocks=40)
ob_reqs = poisson_trace(32, rate=3.0, vocab=cfg.vocab_size,
                        prompt_lens=(6, 12), gen_lens=(6, 8), seed=31)


def timed_run(e, tracer=None):
    e.stats, e.completions = ServeStats(), []
    if tracer is not None:
        tracer.clear()
    comps = e.run(clone(ob_reqs))
    return comps, e.stats.wall_s


e_off = ServeEngine(cfg, ob_eng, mesh, params, opts)
ob_tr = Tracer()
e_on = ServeEngine(cfg, ob_eng, mesh, params, opts, tracer=ob_tr)
e_off.run(clone(ob_reqs))  # warm jit caches (compile excluded for both)
e_on.run(clone(ob_reqs))
wall_off = wall_on = None
comp_off = comp_on = None
for _ in range(5):
    comp_off, w = timed_run(e_off)
    wall_off = w if wall_off is None else min(wall_off, w)
    comp_on, w = timed_run(e_on, ob_tr)
    wall_on = w if wall_on is None else min(wall_on, w)
try:
    ob_rep = validate_spans(ob_tr.events)
    ob_viol = 0
except TraceInvariantError as ex:
    ob_rep, ob_viol = {}, len(ex.violations)
ob_dir = os.path.join("benchmarks", "results")
os.makedirs(ob_dir, exist_ok=True)
write_perfetto(ob_tr.events,
               os.path.join(ob_dir, "obs_overhead.perfetto.json"))
write_events(ob_tr.events, os.path.join(ob_dir, "obs_overhead.events.jsonl"))
write_metrics(e_on.stats.snapshot(),
              os.path.join(ob_dir, "obs_overhead.metrics.jsonl"))
obs = {
    "n_requests": len(ob_reqs),
    "token_mismatches": sum(a.tokens != b.tokens
                            for a, b in zip(comp_off, comp_on)),
    "n_events": len(ob_tr.events),
    "span_violations": ob_viol, "span_report": ob_rep,
    "wall_s_off": wall_off, "wall_s_on": wall_on,
    "off": e_off.stats.summary(), "on": e_on.stats.summary(),
}

# --- continuous vs static (uniform prompts, staggered budgets) ------------
PROMPT, MAX_GEN, N_REQ = 8, 8, 18
max_seq = PROMPT + MAX_GEN
eng = pl.EngineConfig(n_trials=1, n_microbatches=3, microbatch=2, n_stages=4,
                      data_size=1, max_seq=max_seq, cache_dtype=jnp.float32,
                      prefill_chunks=2)
params_cs = pl.init_trial_params(cfg, eng, plan, jax.random.PRNGKey(0),
                                 max_pos=max_seq)
rng = np.random.default_rng(0)
t, reqs = 0.0, []
for i in range(N_REQ):
    t += float(rng.exponential(1.0 / 2.0))
    reqs.append(Request(i, rng.integers(0, cfg.vocab_size,
                                        (PROMPT,)).astype(np.int32),
                        int(rng.integers(2, MAX_GEN + 1)), arrival=t))
engine = ServeEngine(cfg, eng, mesh, params_cs, opts)
cont = engine.run(clone(reqs))
cs = engine.stats
stat, ss = static_serve(cfg, eng, mesh, params_cs, reqs, opts)
mism = sum(a.tokens != b.tokens for a, b in zip(cont, stat))
print(json.dumps({
    "token_mismatches": mism,
    "continuous": cs.summary(), "static": ss.summary(),
    "paged_vs_dense": pvd, "multiarch": mvs, "policies": pol,
    "prefix": pfx, "overcommit": ovc, "spill": spl, "paged_kernel": pk,
    "fused": fa, "spec_decode": sd, "obs": obs}))
"""


def run() -> list:
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
        capture_output=True, text=True, timeout=1100, cwd=ROOT)
    if proc.returncode != 0:
        return [{"name": "serve/error", "us_per_call": -1,
                 "derived": {"stderr": proc.stderr[-500:]}}]
    d = json.loads(proc.stdout.strip().splitlines()[-1])

    def upc(summary):
        # wall microseconds per pipeline call — the honest per-call cost of
        # the row's primary engine run (per-token rates are derived entries)
        return round(1e6 * summary["wall_s"] / max(summary["calls"], 1), 1)

    cont, stat, pvd = d["continuous"], d["static"], d["paged_vs_dense"]
    # the slot-recycling claim is SCHEDULING efficiency, so the gated metric
    # is tokens per engine tick — the deterministic unit both paths share.
    # Wall tok/s is reported but NOT gated: the subprocess's wall clock folds
    # in jit compiles and host jitter, and the static path runs fewer
    # distinct shapes per round (one lockstep decode vs chunked admission
    # waves), so it can "win" wall seconds while losing the schedule
    tptc = cont["tokens_generated"] / max(cont["ticks"], 1)
    tpts = stat["tokens_generated"] / max(stat["ticks"], 1)
    row = {
        "name": "serve/continuous_vs_static",
        "us_per_call": upc(cont),
        "derived": {
            "slot_occupancy_continuous": cont["slot_occupancy"],
            "slot_occupancy_static": stat["slot_occupancy"],
            "decode_occupancy_continuous": cont["decode_occupancy"],
            "decode_occupancy_static": stat["decode_occupancy"],
            "tokens_per_tick_continuous": round(tptc, 3),
            "tokens_per_tick_static": round(tpts, 3),
            "tokens_per_s_continuous": cont["tokens_per_s"],
            "tokens_per_s_static": stat["tokens_per_s"],
            "ttft_p95_continuous": cont.get("ttft_p95"),
            "ttft_p95_static": stat.get("ttft_p95"),
            "tpot_p95_continuous": cont.get("tpot_p95"),
            "token_mismatches": d["token_mismatches"],
        },
    }
    # the slot-recycling claim IS a failure condition: continuous batching
    # must beat lockstep static batching on tokens/tick (occupancy is the
    # mechanism, tokens/tick the outcome) with bit-identical greedy tokens
    if d["token_mismatches"] or tptc <= tpts:
        row["us_per_call"] = -1
    rows = [row]
    dense, paged = pvd["dense"], pvd["paged"]
    row = {
        "name": "serve/paged_vs_dense",
        "us_per_call": upc(paged),
        "derived": {
            "hbm_budget_mb": pvd["budget_mb"],
            "capacity_cells_dense": pvd["cells_dense"],
            "capacity_cells_paged": pvd["cells_paged"],
            "peak_live_dense": dense["peak_live"],
            "peak_live_paged": paged["peak_live"],
            "slot_occupancy_dense": dense["slot_occupancy"],
            "slot_occupancy_paged": paged["slot_occupancy"],
            "tokens_per_s_dense": dense["tokens_per_s"],
            "tokens_per_s_paged": paged["tokens_per_s"],
            "ttft_p95_paged": paged.get("ttft_p95"),
            "pool": f"{pvd['n_blocks']}x{pvd['block_size']}",
            "pool_stalls": paged.get("pool_stalls", 0),
            "token_mismatches": pvd["token_mismatches"],
            "paged_admits_more": pvd["cells_paged"] > pvd["cells_dense"],
        },
    }
    # the paged claim IS a failure condition: equal-HBM paged capacity
    # must beat dense, with bit-identical greedy tokens
    if (pvd["token_mismatches"] or d["token_mismatches"]
            or pvd["cells_paged"] <= pvd["cells_dense"]):
        row["us_per_call"] = -1
    rows.append(row)
    mvs = d["multiarch"]
    row = {
        "name": "serve/multiarch_gang_vs_sequential",
        "us_per_call": upc(mvs["gang"]),
        "derived": {
            "hbm_budget_mb": mvs["budget_mb"],
            "cells_gang_total": mvs["cells_gang"],
            "cells_solo_each": mvs["cells_solo_each"],
            "tokens_per_s_gang": mvs["tokens_per_s_gang"],
            "tokens_per_s_sequential": mvs["tokens_per_s_sequential"],
            "wall_s_gang": mvs["wall_s_gang"],
            "wall_s_sequential": mvs["wall_s_sequential"],
            "slot_occupancy_gang": mvs["gang"]["slot_occupancy"],
            "ttft_p95_gang": mvs["gang"].get("ttft_p95"),
            "tokens_per_arch": mvs["gang"].get("tokens_per_arch"),
            "token_mismatches": mvs["token_mismatches"],
            "gang_beats_sequential": (mvs["tokens_per_s_gang"]
                                      > mvs["tokens_per_s_sequential"]),
        },
    }
    # the co-serving claim IS a failure condition: the K=2 gang must beat
    # two sequential single-arch engines on aggregate tok/s at equal HBM,
    # with bit-identical greedy tokens per request
    if (mvs["token_mismatches"]
            or mvs["tokens_per_s_gang"] <= mvs["tokens_per_s_sequential"]):
        row["us_per_call"] = -1
    rows.append(row)
    pfx = d["prefix"]
    saved = 1.0 - (pfx["prefill_slot_ticks_cache"]
                   / max(pfx["prefill_slot_ticks_nocache"], 1))
    row = {
        "name": "serve/prefix_cache",
        "us_per_call": upc(pfx["cache"]),
        "derived": {
            "pool": pfx["pool"],
            "prefill_slot_ticks_cache": pfx["prefill_slot_ticks_cache"],
            "prefill_slot_ticks_nocache": pfx["prefill_slot_ticks_nocache"],
            "prefill_saved_frac": round(saved, 4),
            "prefill_calls_cache": pfx["prefill_calls_cache"],
            "prefill_calls_nocache": pfx["prefill_calls_nocache"],
            "ttft_mean_cache": pfx["ttft_mean_cache"],
            "ttft_mean_nocache": pfx["ttft_mean_nocache"],
            "prefix_hits": pfx["prefix_hits"],
            "prefix_hit_tokens": pfx["prefix_hit_tokens"],
            "prefix_evictions": pfx["prefix_evictions"],
            "cow_forks": pfx["cow_forks"],
            "token_mismatches": pfx["token_mismatches"],
        },
    }
    # the prefix-cache claim IS a failure condition: >= 30% fewer prefill
    # slot-ticks and lower mean TTFT on the 50%-shared trace at equal HBM,
    # with bit-identical greedy tokens and real hits
    if (pfx["token_mismatches"] or pfx["prefix_hits"] == 0
            or saved < 0.30
            or pfx["ttft_mean_cache"] >= pfx["ttft_mean_nocache"]):
        row["us_per_call"] = -1
    rows.append(row)
    ovc = d["overcommit"]
    oc10, oc15 = ovc["oc10"], ovc["oc15"]
    # sustained throughput in engine ticks (the scheduling unit), not wall
    # seconds: both runs emit bit-identical tokens, so tokens/tick is exact
    # and immune to host load — wall tok/s is reported but never gated on
    tpt10 = oc10["tokens_generated"] / max(oc10["ticks"], 1)
    tpt15 = oc15["tokens_generated"] / max(oc15["ticks"], 1)
    row = {
        "name": "serve/overcommit_retract",
        "us_per_call": upc(oc15),
        "derived": {
            "n_requests": ovc["n_requests"],
            "pool": ovc["pool"],
            "tokens_per_tick_oc10": round(tpt10, 3),
            "tokens_per_tick_oc15": round(tpt15, 3),
            "tokens_per_s_oc10": oc10["tokens_per_s"],
            "tokens_per_s_oc15": oc15["tokens_per_s"],
            "ticks_oc10": oc10["ticks"], "ticks_oc15": oc15["ticks"],
            "peak_live_oc10": oc10["peak_live"],
            "peak_live_oc15": oc15["peak_live"],
            "retractions": ovc["retractions"],
            "restored": ovc["restored"],
            "swap_out_blocks": ovc["swap_out_blocks"],
            "swap_in_blocks": ovc["swap_in_blocks"],
            "completed_oc10": ovc["completed_oc10"],
            "completed_oc15": ovc["completed_oc15"],
            "token_mismatches": ovc["token_mismatches"],
        },
    }
    # the overcommit claim IS a failure condition: retraction must beat the
    # preemption-free schedule on sustained tokens/tick over the bursty
    # trace, complete every request (both runs draining = no deadlock) with
    # bit-identical greedy tokens and at least one real retraction
    if (ovc["token_mismatches"]
            or ovc["completed_oc15"] != ovc["n_requests"]
            or ovc["completed_oc10"] != ovc["n_requests"]
            or ovc["retractions"] == 0
            or tpt15 <= tpt10):
        row["us_per_call"] = -1
    rows.append(row)
    spl = d["spill"]
    host, nohost = spl["host"], spl["nohost"]
    row = {
        "name": "serve/host_prefix_spill",
        "us_per_call": upc(host),
        "derived": {
            "n_requests": spl["n_requests"],
            "pool": spl["pool"],
            "prefix_hit_tokens_host": host["prefix_hit_tokens"],
            "prefix_hit_tokens_nohost": nohost["prefix_hit_tokens"],
            "host_hit_tokens": host["host_hit_tokens"],
            "prefix_spills": host["prefix_spills"],
            "prefix_evictions_host": host["prefix_evictions"],
            "prefix_evictions_nohost": nohost["prefix_evictions"],
            "swap_in_blocks": host["swap_in_blocks"],
            "token_mismatches": spl["token_mismatches"],
        },
    }
    # the spill claim IS a failure condition: at equal HBM the host tier
    # must raise the effective prefix-hit token count (spilled nodes stay
    # matchable and swap back in) with bit-identical greedy tokens
    if (spl["token_mismatches"]
            or host["prefix_hit_tokens"] <= nohost["prefix_hit_tokens"]
            or host["host_hit_tokens"] == 0):
        row["us_per_call"] = -1
    rows.append(row)
    pol = d["policies"]
    rows.append({
        "name": "serve/admission_policies",
        "us_per_call": pol["fcfs"]["us_per_call"],
        "derived": {f"{p}_{k}": v for p, s in pol.items()
                    for k, v in s.items()},
    })
    pk = d["paged_kernel"]
    longest = str(max(int(s) for s in pk["seqs"]))
    top = pk["seqs"][longest]
    derived = {
        "max_seq_provisioned": pk["max_seq"],
        "block_size": pk["block_size"],
        "oracle_mismatches": top["oracle_mismatches"],
        "speedup_at_longest": round(
            top["kernel"]["tokens_per_s"]
            / max(top["gather"]["tokens_per_s"], 1e-9), 3),
    }
    for s in sorted(pk["seqs"], key=int):
        e = pk["seqs"][s]
        derived[f"tokens_per_s_kernel_s{s}"] = e["kernel"]["tokens_per_s"]
        derived[f"tokens_per_s_gather_s{s}"] = e["gather"]["tokens_per_s"]
        derived[f"token_mismatches_s{s}"] = e["token_mismatches"]
    row = {
        "name": "serve/paged_kernel_vs_gather",
        "us_per_call": upc(top["kernel"]),
        "derived": derived,
    }
    # the kernel-path claim IS a failure condition: attending straight from
    # the block pool through trimmed tables must beat the gather path's
    # O(max_seq) materialization at the longest tested sequence length, with
    # greedy tokens bit-identical to the gather path at EVERY length and to
    # the single-device oracle at the longest
    if (any(pk["seqs"][s]["token_mismatches"] for s in pk["seqs"])
            or top["oracle_mismatches"]
            or top["kernel"]["tokens_per_s"]
            <= top["gather"]["tokens_per_s"]):
        row["us_per_call"] = -1
    rows.append(row)
    fa = d["fused"]
    fu, sp = fa["fused"], fa["split"]
    row = {
        "name": "serve/fused_admission",
        "us_per_call": upc(fu),
        "derived": {
            "n_requests": fa["n_requests"],
            "calls_fused": fu["calls"],
            "calls_split": sp["calls"],
            "mixed_calls": fu.get("mixed_calls", 0),
            "mixed_fill_ratio": fu.get("mixed_fill_ratio"),
            "decode_occupancy_fused": fu["decode_occupancy"],
            "decode_occupancy_split": sp["decode_occupancy"],
            "tokens_per_s_fused": fu["tokens_per_s"],
            "tokens_per_s_split": sp["tokens_per_s"],
            "ttft_p95_fused": fu.get("ttft_p95"),
            "ttft_p95_split": sp.get("ttft_p95"),
            "token_mismatches": fa["token_mismatches"],
            "latency_mismatches": fa["latency_mismatches"],
            "oracle_mismatches": fa["oracle_mismatches"],
        },
    }
    # the fused-admission claim IS a failure condition: folding the round's
    # prefill waves + decode into one mixed-tick program must issue strictly
    # fewer pipeline calls on the admission-heavy trace without degrading
    # decode occupancy, with greedy tokens AND tick latencies bit-identical
    # to the split schedule and tokens matching the single-device oracle
    if (fa["token_mismatches"] or fa["latency_mismatches"]
            or fa["oracle_mismatches"]
            or fu["calls"] >= sp["calls"]
            or fu["decode_occupancy"] < sp["decode_occupancy"]):
        row["us_per_call"] = -1
    rows.append(row)
    sd = d["spec_decode"]
    speedup = (sd["ticks_per_token_base_paged"]
               / max(sd["ticks_per_token_spec_paged"], 1e-9))
    row = {
        "name": "serve/spec_decode",
        "us_per_call": upc(sd["spec"]),
        "derived": {
            "n_requests": sd["n_requests"],
            "spec_gamma": sd["gamma"],
            "target_ticks_per_token_base": sd["ticks_per_token_base_paged"],
            "target_ticks_per_token_spec": sd["ticks_per_token_spec_paged"],
            "target_ticks_per_token_base_dense":
                sd["ticks_per_token_base_dense"],
            "target_ticks_per_token_spec_dense":
                sd["ticks_per_token_spec_dense"],
            "speedup_target_ticks": round(speedup, 3),
            "acceptance_rate": sd["perfect"]["acceptance_rate"],
            "acceptance_rate_mixed": sd["mixed"]["acceptance_rate"],
            "draft_calls": sd["perfect"]["spec_draft_calls"],
            "verify_calls": sd["perfect"]["spec_verify_calls"],
            "bonus_tokens": sd["perfect"]["spec_bonus_tokens"],
            "rollback_blocks_mixed": sd["rollback_blocks_mixed"],
            "all_blocks_freed": sd["all_free_after"],
            "token_mismatches": sd["token_mismatches"],
            "oracle_mismatches": sd["oracle_mismatches"],
        },
    }
    # the speculation claim IS a failure condition: with a perfect drafter
    # the target must spend >= 1.3x fewer of ITS OWN ticks per output token
    # than the target-only engine, greedy tokens must be bit-identical to
    # the baseline AND the single-device oracle across dense/paged/kernel
    # AND the rejecting mixed-drafter run, rejection must actually roll
    # blocks back, and every pool block must be free after drain
    if (sd["token_mismatches"] or sd["oracle_mismatches"]
            or speedup < 1.3 or sd["rollback_blocks_mixed"] == 0
            or not sd["all_free_after"]):
        row["us_per_call"] = -1
    rows.append(row)
    obs = d["obs"]
    off, on = obs["off"], obs["on"]
    tpt_off = off["tokens_generated"] / max(off["ticks"], 1)
    tpt_on = on["tokens_generated"] / max(on["ticks"], 1)
    # tokens equal + wall ratio >= 0.95 <=> tracing-on wall tok/s within 5%
    wall_ratio = obs["wall_s_off"] / max(obs["wall_s_on"], 1e-9)
    row = {
        "name": "serve/obs_overhead",
        "us_per_call": upc(on),
        "derived": {
            "n_requests": obs["n_requests"],
            "n_events": obs["n_events"],
            "span_violations": obs["span_violations"],
            "requests_traced": obs["span_report"].get("requests", 0),
            "completed_traced": obs["span_report"].get("completed", 0),
            "ticks_off": off["ticks"], "ticks_on": on["ticks"],
            "tokens_per_tick_off": round(tpt_off, 3),
            "tokens_per_tick_on": round(tpt_on, 3),
            "wall_s_off": round(obs["wall_s_off"], 4),
            "wall_s_on": round(obs["wall_s_on"], 4),
            "wall_ratio_off_over_on": round(wall_ratio, 4),
            "token_mismatches": obs["token_mismatches"],
        },
    }
    # the telemetry claim IS a failure condition: tracing OFF must change
    # nothing (bit-identical greedy tokens, identical tick count and
    # tokens/tick vs the traced engine), tracing ON must stay within 5%
    # wall tok/s, and the emitted trace must pass the span validator
    if (obs["token_mismatches"] or obs["span_violations"]
            or off["ticks"] != on["ticks"] or tpt_off != tpt_on
            or wall_ratio < 0.95):
        row["us_per_call"] = -1
    rows.append(row)
    return rows
