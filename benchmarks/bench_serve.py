"""Continuous vs static batching under a Poisson arrival trace (subprocess,
8 fake host devices): tokens/sec and steady-state slot occupancy. The claim
under test is Hydra's slot-filling insight applied to serving — recycling a
finished request's pipeline slot immediately keeps occupancy near 1 where
the lockstep batch decays as it drains."""
import json
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = r"""
import json, os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ASSIGNED_ARCHS
from repro.core import pipeline as pl
from repro.core.partitioner import plan_stages
from repro.launch.mesh import make_test_mesh
from repro.models.layers import ModelOptions
from repro.serve import Request, ServeEngine, static_serve

cfg = ASSIGNED_ARCHS["chatglm3-6b"].reduced()
opts = ModelOptions()
mesh = make_test_mesh(1, 4)
PROMPT, MAX_GEN, N_REQ = 8, 8, 18
max_seq = PROMPT + MAX_GEN
eng = pl.EngineConfig(n_trials=1, n_microbatches=3, microbatch=2, n_stages=4,
                      data_size=1, max_seq=max_seq, cache_dtype=jnp.float32,
                      prefill_chunks=2)
plan = plan_stages(cfg, eng.n_stages)
params = pl.init_trial_params(cfg, eng, plan, jax.random.PRNGKey(0),
                              max_pos=max_seq)

# staggered Poisson trace: uniform prompts (static needs them), ragged
# generation budgets (what staggers completion and idles static slots)
rng = np.random.default_rng(0)
t, reqs = 0.0, []
for i in range(N_REQ):
    t += float(rng.exponential(1.0 / 2.0))
    reqs.append(Request(i, rng.integers(0, cfg.vocab_size,
                                        (PROMPT,)).astype(np.int32),
                        int(rng.integers(2, MAX_GEN + 1)), arrival=t))

engine = ServeEngine(cfg, eng, mesh, params, opts)
cont = engine.run([Request(r.rid, r.prompt.copy(), r.max_new_tokens,
                           r.arrival) for r in reqs])
cs = engine.stats
stat, ss = static_serve(cfg, eng, mesh, params, reqs, opts)
mism = sum(a.tokens != b.tokens for a, b in zip(cont, stat))
print(json.dumps({
    "token_mismatches": mism,
    "continuous": cs.summary(), "static": ss.summary()}))
"""


def run() -> list:
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD],
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")},
        capture_output=True, text=True, timeout=580, cwd=ROOT)
    if proc.returncode != 0:
        return [{"name": "serve/error", "us_per_call": -1,
                 "derived": {"stderr": proc.stderr[-500:]}}]
    d = json.loads(proc.stdout.strip().splitlines()[-1])
    cont, stat = d["continuous"], d["static"]
    return [{
        "name": "serve/continuous_vs_static",
        "us_per_call": round(1e6 / max(cont["tokens_per_s"], 1e-9), 1),
        "derived": {
            "slot_occupancy_continuous": cont["slot_occupancy"],
            "slot_occupancy_static": stat["slot_occupancy"],
            "decode_occupancy_continuous": cont["decode_occupancy"],
            "decode_occupancy_static": stat["decode_occupancy"],
            "tokens_per_s_continuous": cont["tokens_per_s"],
            "tokens_per_s_static": stat["tokens_per_s"],
            "token_mismatches": d["token_mismatches"],
        },
    }]
