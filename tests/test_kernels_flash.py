"""Pallas flash-attention kernel vs ref.py oracle: shape/dtype sweep in
interpret mode (kernel body executed on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.layers import repeat_kv

RNG = np.random.default_rng(1)

SWEEP = [
    # b, sq, sk, hq, hkv, hd, causal, window, off, dtype
    (1, 64, 64, 4, 2, 16, True, 0, 0, jnp.float32),
    (2, 33, 33, 4, 4, 32, True, 0, 0, jnp.float32),
    (1, 128, 128, 8, 2, 16, True, 24, 0, jnp.float32),
    (1, 16, 48, 4, 1, 16, True, 0, 32, jnp.float32),
    (2, 40, 40, 4, 2, 16, False, 0, 0, jnp.bfloat16),
    (1, 72, 72, 2, 2, 64, True, 0, 0, jnp.bfloat16),
    (1, 8, 8, 1, 1, 8, True, 0, 0, jnp.float32),
]


@pytest.mark.parametrize("b,sq,sk,hq,hkv,hd,causal,window,off,dt", SWEEP)
def test_flash_vs_ref(b, sq, sk, hq, hkv, hd, causal, window, off, dt):
    q = jnp.asarray(RNG.normal(size=(b, sq, hq, hd)), dt)
    k = jnp.asarray(RNG.normal(size=(b, sk, hkv, hd)), dt)
    v = jnp.asarray(RNG.normal(size=(b, sk, hkv, hd)), dt)
    g = hq // hkv
    r = ref.flash_attention_ref(q, repeat_kv(k, g), repeat_kv(v, g),
                                causal=causal, window=window, kv_offset=off)
    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            kv_offset=off, block_q=16, block_k=16)
    tol = 2e-2 if dt == jnp.bfloat16 else 2e-5
    err = float(jnp.max(jnp.abs(r.astype(jnp.float32)
                                - o.astype(jnp.float32))))
    assert err < tol, err


def test_flash_block_size_invariance():
    q = jnp.asarray(RNG.normal(size=(1, 96, 4, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 96, 2, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 96, 2, 16)), jnp.float32)
    outs = [ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
            for bq, bk in [(16, 16), (32, 16), (16, 32), (96, 96)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=2e-5)
