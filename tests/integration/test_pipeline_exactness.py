"""Hydra's pipelined multi-trial training must EXACTLY reproduce per-trial
single-device training — the paper's desideratum D3. Trains K trials for a
few steps both ways and compares losses and final parameters.

Collected by pytest (8 fake host devices come from tests/conftest.py);
``python tests/integration/test_pipeline_exactness.py [arch] [fsdp] [skip]``
still works standalone.
"""
import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import jax  # noqa: E402
import pytest  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ASSIGNED_ARCHS  # noqa: E402
from repro.core import pipeline as pl  # noqa: E402
from repro.core.partitioner import plan_stages  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402
from repro.data.pipeline import TrainBatches  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models.layers import ModelOptions  # noqa: E402
from repro.optim.adamw import AdamW  # noqa: E402


def sequential_reference(cfg, opts, params_stacked, batches, hparams,
                         optimizer, n_steps, eng):
    """Oracle: each trial trained independently (single device, no pipeline).

    Reuses the identical math: per-trial loss = mean over M microbatches of
    per-microbatch mean CE (+ MoE aux with the same coefficient).
    """
    K, M = eng.n_trials, eng.n_microbatches
    D = eng.data_size * eng.pod_size

    def one_trial_loss(p_k, batch_k):
        def slot_loss(m, d):
            # the system's objective is defined per data-shard microbatch
            # (CE is linear in the split; the MoE aux is Switch-style
            # per-shard) — slice the same (mb, seq) shard the engine sees
            def shard(x):
                mb = x.shape[1] // D
                return jax.lax.dynamic_slice_in_dim(x[m], d * mb, mb, axis=0)

            sub = {"tokens": shard(batch_k["tokens"]),
                   "labels": shard(batch_k["labels"])}
            if "frontend_embeds" in batch_k:
                sub["frontend_embeds"] = shard(batch_k["frontend_embeds"])
            if "mrope_pos" in batch_k:
                mp = batch_k["mrope_pos"][m]  # (3, mbg, seq)
                mb = mp.shape[1] // D
                sub["mrope_pos"] = jax.lax.dynamic_slice_in_dim(
                    mp, d * mb, mb, axis=1)
            logits, _, aux = lm.forward(cfg, opts, p_k, sub, mode="train")
            loss = lm.cross_entropy(logits, sub["labels"])
            return loss, aux

        ms, ds = jnp.meshgrid(jnp.arange(M), jnp.arange(D), indexing="ij")
        losses, auxes = jax.vmap(jax.vmap(slot_loss))(ms, ds)
        total = losses.mean()
        if cfg.moe is not None:
            total = total + cfg.moe.load_balance_coef * auxes.mean()
        return total, losses.mean()

    params = params_stacked
    opt_state = optimizer.init(params)
    last_loss = None

    @jax.jit
    def step_fn(params, opt_state, batch, step):
        def trial_grad(p_k, b_k, lr, wd):
            (_, loss), g = jax.value_and_grad(one_trial_loss, has_aux=True)(
                p_k, b_k)
            return loss, g

        losses, grads = jax.vmap(trial_grad)(
            params, batch, hparams["lr"], hparams["wd"])
        gnorm = jax.vmap(lambda g: jnp.sqrt(sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(g))))(grads)
        params, opt_state = optimizer.update(params, grads, opt_state,
                                             hparams, step, grad_norm=gnorm)
        return params, opt_state, losses

    for step in range(n_steps):
        params, opt_state, last_loss = step_fn(
            params, opt_state, batches[step], jnp.asarray(step, jnp.int32))
    return params, np.asarray(last_loss)


def run_case(arch="chatglm3-6b", fsdp=False, skip_bubbles=False):
    n_dev = jax.device_count()
    assert n_dev >= 8, n_dev
    mesh = make_test_mesh(2, 4)
    cfg = ASSIGNED_ARCHS[arch].reduced()
    opts = ModelOptions(remat=True,
                        moe_capacity_factor=64.0)  # dropless => oracle-exact
    eng = pl.EngineConfig(n_trials=2, n_microbatches=3, microbatch=2,
                          n_stages=4, data_size=2, fsdp=fsdp,
                          vocab_parallel=True, skip_bubbles=skip_bubbles,
                          layer_remat=not skip_bubbles)
    seq = 16
    plan = plan_stages(cfg, eng.n_stages)
    key = jax.random.PRNGKey(0)
    params = pl.init_trial_params(cfg, eng, plan, key, max_pos=seq)
    optimizer = AdamW(grad_clip=1.0)
    hparams = {"lr": jnp.asarray([3e-3, 1e-3]),
               "wd": jnp.asarray([0.0, 0.01])}

    data = TrainBatches(cfg, eng, seq, seed=0)
    n_steps = 3
    batches = [jax.tree.map(jnp.asarray, data.batch_for_step(s))
               for s in range(n_steps)]
    data.close()

    # copy before the pipelined run donates the buffers
    ref_params = jax.tree.map(lambda x: jnp.array(x), params)

    # --- Hydra pipelined run ------------------------------------------------
    step_fn = pl.make_train_step(cfg, opts, eng, mesh, optimizer)
    p = params
    o = optimizer.init(params)
    for s in range(n_steps):
        p, o, metrics = step_fn(p, o, batches[s], hparams,
                                jnp.asarray(s, jnp.int32))
    pipe_loss = np.asarray(metrics["loss"])
    pipe_params = jax.device_get(p)

    # --- oracle -------------------------------------------------------------
    ref_final, ref_loss = sequential_reference(
        cfg, opts, ref_params, batches, hparams, optimizer, n_steps, eng)

    err_loss = np.max(np.abs(pipe_loss - ref_loss))
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        pipe_params, jax.device_get(ref_final))
    err_params = max(jax.tree.leaves(diffs))
    tol = 2e-4
    assert err_loss < tol, (arch, pipe_loss, ref_loss)
    assert err_params < 5e-3, sorted(
        jax.tree_util.tree_leaves_with_path(diffs),
        key=lambda kv: -kv[1])[:5]


@pytest.mark.parametrize("arch", ["chatglm3-6b", "granite-moe-3b-a800m"])
def test_pipeline_exactness(arch):
    run_case(arch)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-7b"])
def test_pipeline_exactness_ssm_hybrid(arch):
    run_case(arch)


def test_pipeline_exactness_fsdp():
    run_case("chatglm3-6b", fsdp=True)


if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "chatglm3-6b"
    fsdp = "fsdp" in sys.argv[2:]
    skip = "skip" in sys.argv[2:]
    run_case(arch, fsdp, skip)
    print("EXACTNESS OK")
