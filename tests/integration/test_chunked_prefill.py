"""Chunked prefill (sequence chunks as Hydra pipeline slots) must match plain
prefill exactly — tokens and caches — across attention/SSM/hybrid families.

Collected by pytest (8 fake host devices come from tests/conftest.py);
``python tests/integration/test_chunked_prefill.py`` still works standalone.
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs import ASSIGNED_ARCHS  # noqa: E402
from repro.core import pipeline as pl  # noqa: E402
from repro.core.partitioner import plan_stages  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402
from repro.models.layers import ModelOptions  # noqa: E402


@pytest.mark.parametrize("arch",
                         ["chatglm3-6b", "falcon-mamba-7b", "zamba2-7b"])
def test_chunked_prefill_matches_plain(arch):
    cfg = ASSIGNED_ARCHS[arch].reduced()
    opts = ModelOptions(moe_capacity_factor=64.0)
    mesh = make_test_mesh(2, 4)
    seq, nc = 16, 4
    mbg = 4
    # plain prefill: 2 request groups, full seq
    eng_p = pl.EngineConfig(n_trials=1, n_microbatches=2, microbatch=2,
                            n_stages=4, data_size=2, max_seq=seq,
                            cache_dtype=jnp.float32)
    # chunked: same 2 groups × 4 chunks of 4 tokens
    eng_c = pl.EngineConfig(n_trials=1, n_microbatches=8, microbatch=2,
                            n_stages=4, data_size=2, max_seq=seq,
                            cache_dtype=jnp.float32, prefill_chunks=nc)
    plan = plan_stages(cfg, 4)
    params = pl.init_trial_params(cfg, eng_p, plan, jax.random.PRNGKey(0),
                                  max_pos=seq)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (1, 2, mbg, seq), np.int32)

    pre = pl.make_serve_step(cfg, opts, eng_p, mesh, "prefill")
    cache_p = pl.serve_cache_struct(cfg, eng_p, dry_run=False)
    cache_p, tok_p, _ = pre(params, cache_p, {"tokens": jnp.asarray(toks)})

    chn = pl.make_serve_step(cfg, opts, eng_c, mesh, "prefill")
    toks_c = toks.reshape(1, 2, mbg, nc, seq // nc).transpose(
        0, 1, 3, 2, 4).reshape(1, 8, mbg, seq // nc)
    cache_c = pl.serve_cache_struct(cfg, eng_c, dry_run=False)
    cache_c, tok_c, _ = chn(params, cache_c, {"tokens": jnp.asarray(toks_c)})

    # final-chunk next-token must match plain prefill's next-token
    tok_c_last = np.asarray(tok_c).reshape(1, 2, nc, mbg)[:, :, -1]
    mism = int((np.asarray(tok_p) != tok_c_last).sum())
    assert mism == 0, f"{arch}: {mism}/{tok_c_last.size} token mismatches"
    # caches must match too
    cdiff = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(cache_p), jax.tree.leaves(cache_c)))
    assert cdiff < 5e-4, f"{arch}: cache max diff {cdiff:.2e}"


if __name__ == "__main__":
    for a in ("chatglm3-6b", "falcon-mamba-7b", "zamba2-7b"):
        test_chunked_prefill_matches_plain(a)
    print("CHUNKED PREFILL OK")
