"""The pipelined serving engine (prefill + decode over the stage ring) must
reproduce the single-device forward exactly — greedy tokens identical.

Collected by pytest (8 fake host devices come from tests/conftest.py);
``python tests/integration/test_serve_pipeline.py [arch]`` still works
standalone.
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8"
                               ).strip()

import sys  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs import ASSIGNED_ARCHS  # noqa: E402
from repro.core import pipeline as pl  # noqa: E402
from repro.core.partitioner import plan_stages  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models.layers import ModelOptions  # noqa: E402


@pytest.mark.parametrize("arch", ["chatglm3-6b", "falcon-mamba-7b"])
def test_serve_pipeline_matches_single_device(arch):
    mesh = make_test_mesh(2, 4)
    cfg = ASSIGNED_ARCHS[arch].reduced()
    opts = ModelOptions(moe_capacity_factor=64.0)
    prompt_len, gen_len = 12, 6
    max_seq = prompt_len + gen_len
    eng = pl.EngineConfig(n_trials=1, n_microbatches=3, microbatch=2,
                          n_stages=4, data_size=2, max_seq=max_seq,
                          cache_dtype=jnp.float32)
    plan = plan_stages(cfg, eng.n_stages)
    params = pl.init_trial_params(cfg, eng, plan, jax.random.PRNGKey(0),
                                  max_pos=max_seq)
    mbg = eng.microbatch * eng.data_size
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (1, eng.n_microbatches, mbg, prompt_len), np.int32))

    prefill = pl.make_serve_step(cfg, opts, eng, mesh, "prefill")
    decode = pl.make_serve_step(cfg, opts, eng, mesh, "decode")
    cache = pl.serve_cache_struct(cfg, eng, dry_run=False)
    cache, tok, _ = prefill(params, cache, {"tokens": prompts})
    pipe_tokens = [np.asarray(tok)]
    pos = prompt_len
    for _ in range(gen_len - 1):
        cache, tok, _ = decode(params, cache, {
            "tokens": jnp.asarray(pipe_tokens[-1][..., None]),
            "positions": jnp.full((1, eng.n_microbatches, mbg), pos,
                                  jnp.int32)})
        pipe_tokens.append(np.asarray(tok))
        pos += 1
    pipe = np.stack(pipe_tokens, axis=-1)  # (1, M, mbg, gen)

    # oracle: single-device greedy decode per slot (padded param stack OK —
    # lm.forward masks padded layers automatically)
    p1 = jax.tree.map(lambda x: x[0], params)  # drop trial axis
    vpad = eng.padded_vocab(cfg.vocab_size)
    if vpad != cfg.vocab_size:
        p1["embed"]["tok"] = p1["embed"]["tok"][:cfg.vocab_size]
        p1["head"] = p1["head"][:, :cfg.vocab_size]
    mism = 0
    for m in range(eng.n_microbatches):
        toks = prompts[0, m]
        cache1 = lm.init_cache(cfg, mbg, max_seq, cache_dtype=jnp.float32)
        logits, cache1, _ = lm.forward(cfg, opts, p1, {"tokens": toks},
                                       mode="prefill", cache=cache1)
        nxt = jnp.argmax(logits[:, -1], -1)
        oracle = [np.asarray(nxt)]
        for t in range(gen_len - 1):
            logits, cache1, _ = lm.forward(
                cfg, opts, p1, {"tokens": oracle[-1][..., None]},
                mode="decode", cache=cache1,
                kv_offset=jnp.full((mbg,), prompt_len + t, jnp.int32))
            oracle.append(np.asarray(jnp.argmax(logits[:, 0], -1)))
        oracle = np.stack(oracle, axis=-1)  # (mbg, gen)
        mism += int((oracle != pipe[0, m]).sum())
    total = eng.n_microbatches * mbg * gen_len
    assert mism == 0, (f"arch={arch}: pipelined serving diverged from "
                       f"single-device oracle ({mism}/{total} tokens)")


if __name__ == "__main__":
    test_serve_pipeline_matches_single_device(
        sys.argv[1] if len(sys.argv) > 1 else "chatglm3-6b")
    print("SERVE PIPELINE OK")
