"""Config registry: exact assigned numbers, param counts vs published sizes,
reduced smoke configs, shape applicability."""
import jax.numpy as jnp
import pytest

from repro.configs import (ASSIGNED_ARCHS, REGISTRY, SHAPES, get_config,
                           input_specs, list_archs, shape_applicable)


EXPECTED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab, ~params B)
    "yi-34b": (60, 7168, 56, 8, 20480, 64000, 34.4),
    "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152, 16.0),
    "deepseek-67b": (95, 8192, 64, 8, 22016, 102400, 67.4),
    "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024, 6.2),
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048, 1.4),
    "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024, 7.3),
    "zamba2-7b": (81, 3584, 32, 32, 14336, 32000, 6.8),
    "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064, 72.7),
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155, 3.4),
    "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048, 101.7),
}


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_assigned_config_numbers(name):
    cfg = get_config(name)
    nl, d, h, kv, ff, v, nb = EXPECTED[name]
    assert cfg.n_layers == nl
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.n_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert abs(cfg.param_count() / 1e9 - nb) < 0.15 * nb


def test_registry_covers_ten_assigned():
    assert len(ASSIGNED_ARCHS) == 10
    assert set(EXPECTED) == set(ASSIGNED_ARCHS)
    assert "bert-large" in REGISTRY and "mlp-1m" in REGISTRY
    assert len(list_archs()) == 12


def test_moe_active_params():
    g = get_config("granite-moe-3b-a800m")
    assert g.active_param_count() < g.param_count()
    assert g.moe.n_experts == 40 and g.moe.top_k == 8
    l4 = get_config("llama4-scout-17b-a16e")
    assert l4.moe.n_experts == 16 and l4.moe.top_k == 1
    assert 9e9 < l4.active_param_count() < 13e9


@pytest.mark.parametrize("name", sorted(ASSIGNED_ARCHS))
def test_reduced_configs(name):
    cfg = get_config(name).reduced()
    assert cfg.family == get_config(name).family
    assert cfg.d_model <= 64 and cfg.vocab_size <= 128
    assert cfg.param_count() < 5e6


def test_shapes():
    assert SHAPES["train_4k"].tokens_per_step == 4096 * 256
    assert SHAPES["decode_32k"].tokens_per_step == 128  # one token per seq
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["prefill_32k"].kind == "prefill"


def test_long_context_applicability():
    runs = [n for n in ASSIGNED_ARCHS
            if shape_applicable(get_config(n), SHAPES["long_500k"])[0]]
    assert sorted(runs) == ["falcon-mamba-7b", "zamba2-7b"]


def test_input_specs_modalities():
    vl = input_specs(get_config("qwen2-vl-72b"), SHAPES["train_4k"])
    assert vl["frontend_embeds"].shape == (256, 256, 8192)
    assert vl["mrope_pos"].shape == (3, 256, 4096)
    au = input_specs(get_config("musicgen-medium"), SHAPES["prefill_32k"])
    assert au["frontend_embeds"].shape[1] == 64
    de = input_specs(get_config("yi-34b"), SHAPES["decode_32k"])
    assert de["tokens"].shape == (128, 1)
    assert de["position"].shape == (128,)
    assert de["tokens"].dtype == jnp.int32
