"""Smoke-run every script under examples/ with tiny configs.

The examples are documentation that executes — they rot silently unless CI
runs them (this suite already caught a stale-checkpoint crash in
model_selection.py between successive-halving rungs). Each script runs in a
subprocess on a single forced host device with its smallest configuration;
the assertion is just "exits 0" — correctness of the underlying machinery is
covered by the unit/integration tiers.

A new example script is picked up automatically (parametrized over the
directory listing); give it a tiny-args entry below if its defaults are too
slow for CI.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
EXAMPLES_DIR = os.path.join(ROOT, "examples")
EXAMPLES = sorted(f for f in os.listdir(EXAMPLES_DIR) if f.endswith(".py"))

# per-script tiny-config args (defaults are used when absent)
TINY_ARGS = {
    "model_selection.py": ["--tiny", "--steps", "2"],
    "serve_decode.py": ["--slots", "2", "--n-requests", "6",
                        "--prompt-len", "8", "--gen-len", "4"],
}
TIMEOUT_S = 420


@pytest.mark.parametrize("script", EXAMPLES)
def test_example_runs(script, tmp_path):
    env = {**os.environ,
           "PYTHONPATH": os.path.join(ROOT, "src"),
           # one host device: the examples degrade to their single-device
           # paths (smallest compiles); the forced-8 flag from conftest.py
           # must not leak into the subprocess
           "XLA_FLAGS": "--xla_force_host_platform_device_count=1"}
    args = list(TINY_ARGS.get(script, []))
    if script == "model_selection.py":
        args += ["--ckpt-dir", str(tmp_path / "ckpt")]  # hermetic
    proc = subprocess.run(
        [sys.executable, os.path.join(EXAMPLES_DIR, script), *args],
        env=env, capture_output=True, text=True, timeout=TIMEOUT_S, cwd=ROOT)
    assert proc.returncode == 0, (
        f"examples/{script} exited {proc.returncode}\n"
        f"--- stdout ---\n{proc.stdout[-1500:]}\n"
        f"--- stderr ---\n{proc.stderr[-1500:]}")
