"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the optional hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import simulator as sim
from repro.core.partitioner import partition_costs
from repro.core.pipeline import EngineConfig
from repro.models import layers as L
from repro.models import lm
from repro.serve.paging import BlockAllocator, blocks_for
from repro.serve.prefix_cache import PrefixCache
from repro.serve.store import BlockStore
from repro.serve.transfer import make_null_transfer


@settings(max_examples=30, deadline=None)
@given(n_layers=st.integers(1, 200), n_stages=st.integers(1, 32))
def test_stage_plan_partition_invariants(n_layers, n_stages):
    from repro.configs import get_config
    import dataclasses
    cfg = dataclasses.replace(get_config("chatglm3-6b"), n_layers=n_layers)
    from repro.core.partitioner import plan_stages
    plan = plan_stages(cfg, n_stages)
    # every real layer is owned by exactly one stage; padding only at the end
    owned = sum(plan.real_layers_in_stage(s) for s in range(n_stages))
    assert owned == n_layers
    assert 0 <= plan.pad_fraction < 1
    assert plan.layers_per_stage * n_stages >= n_layers
    assert (plan.layers_per_stage - 1) * n_stages < n_layers


@settings(max_examples=25, deadline=None)
@given(costs=st.lists(st.floats(0.1, 10), min_size=1, max_size=12),
       k=st.integers(1, 5))
def test_partition_costs_validity(costs, k):
    starts = partition_costs(costs, k)
    assert len(starts) == k
    assert starts[0] == 0
    assert all(a <= b for a, b in zip(starts, starts[1:]))
    bounds = starts + [len(costs)]
    got = max((sum(costs[bounds[i]:bounds[i + 1]]) for i in range(k)),
              default=0)
    # lower bounds of the optimum
    assert got >= max(costs) - 1e-9 or got == 0
    assert got >= sum(costs) / k - 1e-9


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 6), s=st.integers(2, 8), m=st.integers(1, 4))
def test_simulator_work_conservation(k, s, m):
    """Makespan x devices >= total work; utilization = work / (makespan·S)."""
    r = sim.simulate_shard_parallel(k, s, m)
    work = k * m * s * 3.0  # fwd 1 + bwd 2 per shard task
    assert r.makespan * s >= work - 1e-9
    np.testing.assert_allclose(r.utilization, work / (r.makespan * s),
                               rtol=1e-9)
    # closed form exactness
    np.testing.assert_allclose(
        r.makespan, sim.theoretical_shard_parallel_makespan(k, s, m),
        rtol=1e-9)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_cross_entropy_shift_invariance(seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, 3, 17)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 17, (2, 3)))
    a = lm.cross_entropy(logits, labels)
    b = lm.cross_entropy(logits + 123.0, labels)  # softmax shift invariance
    np.testing.assert_allclose(float(a), float(b), rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_rms_norm_scale_property(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 5, 8)) + 0.1, jnp.float32)
    y = L.rms_norm(x, jnp.ones((8,)))
    # unit RMS output (up to eps)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=1e-2)
    # scale equivariance: rms_norm(c*x) == rms_norm(x) for c > 0
    y2 = L.rms_norm(x * 7.5, jnp.ones((8,)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 4))
def test_moe_capacity_monotonicity(seed, top_k):
    """Raising capacity can only reduce dropped tokens: with max capacity the
    output equals the dropless mixture; lower capacities stay finite."""
    rng = np.random.default_rng(seed)
    d, e = 8, 4
    p = {"router": jnp.asarray(rng.normal(size=(d, e)), jnp.float32),
         "w_gate": jnp.asarray(rng.normal(size=(e, d, 8)) * .3, jnp.float32),
         "w_up": jnp.asarray(rng.normal(size=(e, d, 8)) * .3, jnp.float32),
         "w_down": jnp.asarray(rng.normal(size=(e, 8, d)) * .3, jnp.float32)}
    x = jnp.asarray(rng.normal(size=(1, 10, d)), jnp.float32)
    lo, _ = L.moe_apply(p, x, n_experts=e, top_k=top_k, capacity_factor=0.5)
    hi, _ = L.moe_apply(p, x, n_experts=e, top_k=top_k, capacity_factor=99.0)
    assert jnp.all(jnp.isfinite(lo)) and jnp.all(jnp.isfinite(hi))
    # dropped-token rows fall back to zero update; norm(lo) <= norm(hi)+tol
    assert float(jnp.linalg.norm(lo)) <= float(jnp.linalg.norm(hi)) + 1e-3


@settings(max_examples=40, deadline=None)
@given(n_blocks=st.integers(1, 12),
       ops=st.lists(st.tuples(
           st.sampled_from(["alloc", "incref", "decref", "fork"]),
           st.integers(0, 10)), max_size=60))
def test_block_allocator_refcount_invariants(n_blocks, ops):
    """The paged-serving allocator under interleaved alloc / incref / decref
    / CoW-fork sequences (the prefix-sharing lifecycle): no double-free, no
    handout of a live block, and pool conservation (used + free == pool) at
    every step — checked against an independent refcount model."""
    a = BlockAllocator(n_blocks=n_blocks, block_size=4)
    model = {}  # id -> refcount (the oracle)

    def pick(i):
        live = sorted(model)
        return live[i % len(live)] if live else None

    for op, arg in ops:
        if op == "alloc":
            n = arg % (n_blocks + 1)
            got = a.alloc(n)
            if len(model) + n > n_blocks:
                assert got is None  # all-or-nothing on exhaustion
            else:
                assert got is not None and len(got) == n
                for b in got:
                    assert b not in model  # never hand out a live block
                    model[b] = 1
        elif op == "incref" and model:
            b = pick(arg)
            a.incref([b])
            model[b] += 1
        elif op == "decref" and model:
            b = pick(arg)
            freed = a.decref([b])
            model[b] -= 1
            if model[b] == 0:
                assert freed == [b]
                del model[b]
            else:
                assert freed == []
        elif op == "fork" and model:  # CoW: private copy, drop shared ref
            got = a.alloc(1)
            if len(model) >= n_blocks:
                assert got is None
            else:
                assert got is not None and got[0] not in model
                model[got[0]] = 1
                b = pick(arg)
                a.decref([b])
                model[b] -= 1
                if model[b] == 0:
                    del model[b]
        # pool conservation + model agreement, every step
        assert a.used_blocks() == len(model)
        assert a.free_blocks() == n_blocks - len(model)
        for b, r in model.items():
            assert a.ref_count(b) == r
    # draining every reference returns the whole pool to the free list
    for b, r in sorted(model.items()):
        a.decref([b] * r)
    assert a.all_free() and a.free_blocks() == n_blocks


@settings(max_examples=25, deadline=None)
@given(n_blocks=st.integers(2, 8), host_blocks=st.integers(0, 5),
       ops=st.lists(st.tuples(
           st.sampled_from(["insert", "hit", "pressure"]),
           st.integers(0, 10 ** 6)), max_size=40))
def test_tiered_store_lifecycle_invariants(n_blocks, host_blocks, ops):
    """The tiered BlockStore + radix cache + transfer engine under
    interleaved insert / hit-acquire / allocation-pressure sequences (the
    spill/restore lifecycle): pool conservation at every step, every
    device-resident tree node keeps its tree reference, the host tier never
    exceeds capacity, spilled nodes stay addressable (no lost blocks), and
    no transfer is left in flight once flushed."""
    bs = 2
    a = BlockAllocator(n_blocks=n_blocks, block_size=bs)
    store = BlockStore(a, host_blocks=host_blocks,
                       transfer=make_null_transfer())
    pc = PrefixCache(store)
    prompts = []  # inserted token streams (hit ops replay them)

    def release(ids):
        for b in ids:
            a.decref([b])

    for op, arg in ops:
        rng = np.random.default_rng(arg)
        if op == "insert":
            plen = bs * int(rng.integers(1, n_blocks + 1)) + 1
            blocks = store.alloc(blocks_for(plen, bs))
            if blocks is not None:
                prompt = rng.integers(0, 50, (plen,)).astype(np.int32)
                pc.insert(0, prompt, blocks)
                prompts.append(prompt)
                release(blocks)  # the request's table closes
        elif op == "hit" and prompts:
            prompt = prompts[arg % len(prompts)]
            eff = pc.acquire(pc.match(0, prompt))
            assert all(b >= 0 for b in eff.block_ids)  # acquire => device
            store.transfer.flush()
            release(eff.block_ids)  # the admitted request completes
        elif op == "pressure":
            got = store.alloc(1 + arg % n_blocks)
            if got is not None:
                release(got)
        # invariants, every step
        assert a.used_blocks() + a.free_blocks() == n_blocks
        assert store.host_used(0) <= host_blocks
        assert store.transfer.pending() == 0 or op == "hit"
        device_nodes = [n for n in pc._walk(0) if n.block >= 0]
        host_nodes = [n for n in pc._walk(0) if n.block < 0]
        for n in device_nodes:
            assert a.ref_count(n.block) >= 1  # tree reference never lost
        for n in host_nodes:
            hb = store.host_get(0, n.host)  # host id stays addressable
            assert hb.owner is n and not hb.pinned
        assert len(host_nodes) == store.host_used(0)
    store.transfer.flush()
    assert store.transfer.pending() == 0 and not store.transfer._in_flight
    # with every request reference released, only the tree holds the pool:
    # each device-resident node exactly once
    device_nodes = [n for n in pc._walk(0) if n.block >= 0]
    assert a.used_blocks() == len(device_nodes)
    for n in device_nodes:
        assert a.ref_count(n.block) == 1


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 5), st.integers(1, 3), st.integers(1, 3))
def test_engine_bubble_fraction(s, k, m):
    eng = EngineConfig(n_trials=k, n_microbatches=m, microbatch=1,
                       n_stages=s, data_size=1)
    assert eng.n_ticks == k * m + s - 1
    np.testing.assert_allclose(eng.bubble_fraction,
                               (s - 1) / (k * m + s - 1))
    # the paper's claim: more trials => smaller bubble
    eng2 = EngineConfig(n_trials=k + 1, n_microbatches=m, microbatch=1,
                        n_stages=s, data_size=1)
    assert eng2.bubble_fraction < eng.bubble_fraction
