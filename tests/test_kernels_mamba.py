"""Pallas mamba selective-scan kernel vs ref.py oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(2)

SWEEP = [
    # b, s, di, n, chunk, block_di, dtype
    (2, 37, 16, 8, 16, 16, jnp.float32),
    (1, 128, 64, 4, 32, 32, jnp.float32),
    (2, 20, 32, 16, 8, 16, jnp.bfloat16),
    (1, 7, 8, 4, 4, 8, jnp.float32),
    (3, 65, 48, 8, 16, 16, jnp.float32),
]


@pytest.mark.parametrize("b,s,di,n,chunk,bdi,dt", SWEEP)
def test_mamba_scan_vs_ref(b, s, di, n, chunk, bdi, dt):
    da = jnp.asarray(np.exp(-np.abs(RNG.normal(size=(b, s, di, n)) * 0.3)), dt)
    dbx = jnp.asarray(RNG.normal(size=(b, s, di, n)) * 0.2, dt)
    c = jnp.asarray(RNG.normal(size=(b, s, n)), dt)
    h0 = jnp.asarray(RNG.normal(size=(b, di, n)) * 0.1, jnp.float32)
    yr, hr = ref.mamba_scan_ref(da, dbx, c, h0)
    yk, hk = ops.mamba_scan(da, dbx, c, h0, chunk=chunk, block_di=bdi)
    tol = 3e-2 if dt == jnp.bfloat16 else 1e-4
    assert float(jnp.max(jnp.abs(
        yr.astype(jnp.float32) - yk.astype(jnp.float32)))) < tol
    assert float(jnp.max(jnp.abs(hr - hk))) < tol


def test_mamba_kernel_inside_model():
    """End-to-end: mamba1_mix with the kernel path equals the jnp path."""
    from repro.configs import ASSIGNED_ARCHS
    from repro.models import lm
    from repro.models.layers import ModelOptions
    import jax
    cfg = ASSIGNED_ARCHS["falcon-mamba-7b"].reduced()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                          cfg.vocab_size)}
    l1, _, _ = lm.forward(cfg, ModelOptions(), params, batch, mode="train")
    l2, _, _ = lm.forward(cfg, ModelOptions(use_mamba_kernel=True), params,
                          batch, mode="train")
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-4)
