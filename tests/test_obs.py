"""Observability layer: bounded reservoirs, the metric registry behind
``ServeStats``, tracer on/off semantics, JSONL + Perfetto export
round-trips, the span validator, and a traced-vs-untraced engine parity
check (tracing must never change the schedule or the tokens).

(Multi-device setup comes from tests/conftest.py — pytest-only module.)"""
import dataclasses  # noqa: E402
import json  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs import ASSIGNED_ARCHS  # noqa: E402
from repro.core import pipeline as pl  # noqa: E402
from repro.core.partitioner import plan_stages  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402
from repro.models.layers import ModelOptions  # noqa: E402
from repro.obs import (NULL_TRACER, TraceInvariantError,  # noqa: E402
                       Tracer, read_events, resolve, to_chrome_trace,
                       validate_spans, write_events, write_metrics,
                       write_perfetto)
from repro.obs.metrics import (DEFAULT_RESERVOIR_CAP, MetricRegistry,
                               Reservoir)  # noqa: E402
from repro.serve import Request, ServeEngine  # noqa: E402
from repro.serve.engine import ServeStats  # noqa: E402


# ---------------------------------------------------------------- metrics --

def test_reservoir_exact_below_cap():
    r = Reservoir("x", cap=100)
    for v in [3.0, 1.0, 2.0]:
        r.append(v)
    assert len(r) == 3 and list(r) == [3.0, 1.0, 2.0]
    assert r.mean_value == 2.0 and r.min_value == 1.0 and r.max_value == 3.0
    assert float(np.mean(r)) == 2.0  # numpy protocol goes via __array__
    assert r.percentile(50) == 2.0
    snap = r.snapshot()
    assert snap["count"] == 3 and snap["sum"] == 6.0
    assert {"min", "max", "mean", "p50", "p95", "p99"} <= set(snap)


def test_reservoir_bounded_above_cap_with_exact_aggregates():
    r = Reservoir("y", cap=64)
    n = 10_000
    for v in range(n):
        r.append(float(v))
    # the sample buffer is bounded; count/sum/min/max stay exact
    assert len(r) == n and len(r.samples) == 64
    assert r.min_value == 0.0 and r.max_value == float(n - 1)
    assert r.snapshot()["sum"] == float(n * (n - 1) // 2)
    # sampled percentiles land inside the true support
    assert 0.0 <= r.percentile(50) <= float(n - 1)


def test_reservoir_deterministic_per_name():
    a, b = Reservoir("det", cap=8), Reservoir("det", cap=8)
    for v in range(1000):
        a.append(float(v))
        b.append(float(v))
    assert list(a) == list(b)  # seeded by name, not global RNG state


def test_registry_idempotent_and_typed():
    reg = MetricRegistry()
    c = reg.counter("ticks")
    assert reg.counter("ticks") is c
    c.value += 3
    assert reg.value("ticks") == 3
    reg.gauge("wall_s")
    reg.set_value("wall_s", 1.5)
    assert reg.value("wall_s") == 1.5
    h = reg.histogram("ttft", cap=4)
    h.append(2.0)
    with pytest.raises(TypeError):
        reg.set_value("ttft", [1.0])  # histograms append, never assign
    snap = reg.snapshot()
    assert snap["ticks"] == 3 and snap["ttft"]["count"] == 1


def test_servestats_facade_routes_through_registry():
    s = ServeStats()
    s.ticks += 4
    s.tokens_generated += 10
    s.wall_s = 2.0
    s.ttft_samples.append(1.0)
    s.ttft_samples.append(3.0)
    s.tpot_samples.append(0.5)
    s.block_usage_samples.append(7)
    assert s.registry.value("ticks") == 4
    assert s.ticks == 4 and s.wall_s == 2.0
    summ = s.summary()
    assert summ["tokens_generated"] == 10
    assert summ["ttft_p50"] == 2.0
    assert "ttft_p99" in summ and "tpot_p99" in summ
    assert summ["peak_blocks_in_use"] == 7
    assert s.ttft_samples.cap == DEFAULT_RESERVOIR_CAP
    with pytest.raises(AttributeError):
        s.not_a_metric  # noqa: B018


# ----------------------------------------------------------------- tracer --

def test_disabled_tracer_emits_nothing():
    for tr in (NULL_TRACER, resolve(None)):
        assert not tr.enabled
        tr.begin_tick(3)
        tr.emit("x", a=1)
        tr.req("admit", 0, k=0)
        tr.round(modes=["decode"])
        tr.span_begin("gang")
        tr.span_end("gang")
        assert len(tr.events) == 0 and len(tr) == 0


def test_tracer_stamps_tick_and_wall():
    tr = Tracer()
    assert resolve(tr) is tr
    tr.begin_tick(5)
    tr.req("admit", 7, k=0, m=1, b=0)
    tr.round(modes=["decode"], occupied=1)
    [admit, rnd] = tr.events
    assert admit["ev"] == "admit" and admit["rid"] == 7
    assert admit["tick"] == 5 and admit["wall"] >= 0.0
    assert rnd["ev"] == "round" and rnd["modes"] == ["decode"]
    tr.clear()
    assert len(tr) == 0


# ----------------------------------------------------------------- export --

def _lifecycle_events():
    tr = Tracer()
    tr.begin_tick(0)
    tr.req("enqueue", 1, arch=0, plen=8)
    tr.begin_tick(1)
    tr.req("admit", 1, k=0, m=0, b=0, plen=8)
    tr.req("prefill_chunk", 1, k=0, m=0, b=0, qlen=4, pos=0)
    tr.round(modes=["append:4"], occupied=1, occupancy=1.0, queues=[0],
             pool_blocks=2, host_depth=[1], inflight=0)
    tr.begin_tick(2)
    tr.req("first_token", 1, k=0, m=0, b=0)
    tr.begin_tick(3)
    tr.req("swap_out", 1, blocks=2)
    tr.req("retract", 1, via="swap", pos=9)
    tr.begin_tick(4)
    tr.req("restore", 1, k=0, m=0, b=0, via="swap")
    tr.begin_tick(5)
    tr.req("complete", 1, tokens=3, ttft=1.0)
    tr.compile("decode", qlen=1, table_width=0)
    tr.span_begin("gang", arch="a", n_trials=2, steps=4)
    tr.span_end("gang", arch="a")
    return tr.events


def test_jsonl_round_trip(tmp_path):
    events = _lifecycle_events()
    path = str(tmp_path / "events.jsonl")
    assert write_events(events, path) == len(events)
    assert read_events(path) == events


def test_metrics_jsonl(tmp_path):
    s = ServeStats()
    s.ticks += 2
    s.ttft_samples.append(1.0)
    path = str(tmp_path / "metrics.jsonl")
    n = write_metrics(s.snapshot(), path)
    recs = [json.loads(x) for x in open(path)]
    assert len(recs) == n
    by_name = {r["metric"]: r for r in recs}
    assert by_name["ticks"]["value"] == 2
    assert by_name["ttft_samples"]["hist"]["count"] == 1


def test_perfetto_trace_structure(tmp_path):
    trace = to_chrome_trace(_lifecycle_events())
    recs = trace["traceEvents"]
    names = [r["name"] for r in recs]
    # one residency slice per (admit|restore)->(retract|complete) interval
    res = [r for r in recs if r["ph"] == "X" and r["name"] == "req 1"]
    assert len(res) == 2
    assert {r["args"]["closed_by"] for r in res} == {"retract", "complete"}
    assert any(r["ph"] == "X" and r["name"].startswith("prefill q4")
               for r in recs)
    for counter in ("device blocks in use", "host tier p0", "arch 0 queue",
                    "occupied cells", "transfer in-flight"):
        assert counter in names
    assert any(r["ph"] == "i" and r["name"] == "first_token" for r in recs)
    assert any(r["name"] == "compile decode" for r in recs)
    gang = [r for r in recs if r["ph"] == "X" and r["name"] == "gang a"]
    assert len(gang) == 1 and gang[0]["dur"] >= 1
    path = str(tmp_path / "t.json")
    assert write_perfetto(_lifecycle_events(), path) == len(recs)
    assert json.load(open(path))["traceEvents"]


def test_perfetto_closes_truncated_residency():
    keep = ("enqueue", "admit", "prefill_chunk", "first_token")
    events = [e for e in _lifecycle_events() if e["ev"] in keep]
    res = [r for r in to_chrome_trace(events)["traceEvents"]
           if r["ph"] == "X" and r["name"] == "req 1"]
    assert len(res) == 1 and res[0]["args"]["closed_by"] == "open"


# -------------------------------------------------------------- validator --

def test_validator_accepts_legal_lifecycle():
    rep = validate_spans(_lifecycle_events())
    assert rep == {"requests": 1, "completed": 1, "retracted_terminal": 0,
                   "violations": 0}


def _drop(events, name):
    return [e for e in events if e["ev"] != name]


@pytest.mark.parametrize("mutate,needle", [
    (lambda evs: _drop(evs, "enqueue"), "'admit' before 'enqueue'"),
    # in-flight events only while resident: queued rid prefilling is illegal
    (lambda evs: _drop(evs, "admit"), "expected 'running'"),
    (lambda evs: _drop(evs, "swap_out"), "without a preceding 'swap_out'"),
    (lambda evs: _drop(evs, "restore"), "state 'retracted'"),
    (lambda evs: evs + [dict(next(e for e in evs if e["ev"] == "complete"),
                             tick=0)], "backwards"),
])
def test_validator_rejects_illegal_traces(mutate, needle):
    with pytest.raises(TraceInvariantError) as err:
        validate_spans(mutate(_lifecycle_events()))
    assert needle in str(err.value).lower()


def test_validator_open_requests_need_allow_open():
    events = _drop(_lifecycle_events(), "complete")
    with pytest.raises(TraceInvariantError):
        validate_spans(events)
    rep = validate_spans(events, allow_open=True)
    assert rep["requests"] == 1 and rep["completed"] == 0


def test_validator_property_interleavings():
    hyp = pytest.importorskip(
        "hypothesis",
        reason="property tests need the optional hypothesis package")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(plans=st.lists(
        st.tuples(st.integers(0, 3),           # retract/restore cycles
                  st.booleans(),               # ends retracted (truncated)
                  st.sampled_from(["swap", "recompute", "requeue"])),
        min_size=1, max_size=6),
        seed=st.integers(0, 2**16))
    def run(plans, seed):
        # interleave legal per-request lifecycles across shuffled rounds:
        # any schedule the engine could emit must satisfy the validator
        rng = np.random.default_rng(seed)
        tr = Tracer()
        script = []  # (rid, step) in per-request order
        for rid, (cycles, trunc, via) in enumerate(plans):
            steps = [("enqueue", {}), ("admit", {"k": 0, "m": 0, "b": rid})]
            for _ in range(cycles):
                if via == "swap":
                    steps.append(("swap_out", {"blocks": 1}))
                steps.append(("retract", {"via": via}))
                steps.append(("restore", {"via": via, "b": rid}))
            if trunc and cycles:
                steps = steps[:-1]  # ends retracted — terminal is legal
            else:
                steps.append(("complete", {"tokens": 1}))
            script.append([(rid, s) for s in steps])
        tick = 0
        while any(script):
            live = [q for q in script if q]
            order = rng.permutation(len(live))
            tr.begin_tick(tick)
            for i in order:
                if live[i] and rng.random() < 0.7:
                    rid, (name, fields) = live[i].pop(0)
                    tr.req(name, rid, **fields)
            tick += 1
        rep = validate_spans(tr.events, allow_open=True)
        assert rep["requests"] == len(plans) and rep["violations"] == 0
        done = sum(1 for c, trunc, _ in plans if not (trunc and c))
        assert rep["completed"] == done
        assert rep["retracted_terminal"] == len(plans) - done

    run()


# ------------------------------------------------- engine trace integration

MAX_SEQ = 20


def _traced_pair():
    cfg = ASSIGNED_ARCHS["chatglm3-6b"].reduced()
    opts = ModelOptions()
    mesh = make_test_mesh(1, 2)
    eng = pl.EngineConfig(n_trials=1, n_microbatches=2, microbatch=1,
                          n_stages=2, data_size=1, max_seq=MAX_SEQ,
                          cache_dtype=jnp.float32, prefill_chunks=2,
                          paged=True, block_size=4, n_blocks=10)
    plan = plan_stages(cfg, eng.n_stages)
    params = pl.init_trial_params(cfg, eng, plan, jax.random.PRNGKey(0),
                                  max_pos=MAX_SEQ)
    rng = np.random.default_rng(3)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    (8 + 4 * (i % 2),)).astype(np.int32),
                    3 + i % 3, arrival=0.7 * i) for i in range(6)]
    return cfg, eng, mesh, params, opts, reqs


def test_engine_trace_matches_untraced_run():
    cfg, eng, mesh, params, opts, reqs = _traced_pair()
    e0 = ServeEngine(cfg, eng, mesh, params, opts)
    comp0 = e0.run([r.clone() for r in reqs])
    assert len(e0.trace.events) == 0  # off = no event churn at all
    tr = Tracer()
    e1 = ServeEngine(cfg, eng, mesh, params, opts, tracer=tr)
    comp1 = e1.run([r.clone() for r in reqs])
    assert [c.tokens for c in comp0] == [c.tokens for c in comp1]
    assert e0.stats.ticks == e1.stats.ticks
    rep = validate_spans(tr.events)
    assert rep["requests"] == len(reqs) == rep["completed"]
    by_ev = {e["ev"] for e in tr.events}
    assert {"enqueue", "admit", "prefill_chunk", "first_token", "complete",
            "round", "compile"} <= by_ev
    rounds = [e for e in tr.events if e["ev"] == "round"]
    assert len(rounds) == e1.stats.ticks
    assert all("pool_blocks" in r for r in rounds)
    assert len(to_chrome_trace(tr.events)["traceEvents"]) > len(reqs)


def test_engine_trace_retraction_lifecycle():
    cfg, eng, mesh, params, opts, _ = _traced_pair()
    tight = dataclasses.replace(eng, n_blocks=6)
    rng = np.random.default_rng(5)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    (10,)).astype(np.int32), 5, arrival=0.0)
            for i in range(4)]
    tr = Tracer()
    e = ServeEngine(cfg, tight, mesh, params, opts, overcommit=1.5,
                    host_blocks=8, tracer=tr)
    comps = e.run([r.clone() for r in reqs], max_ticks=5000)
    assert len(comps) == len(reqs)
    assert e.stats.retractions > 0  # the tight pool must actually preempt
    rep = validate_spans(tr.events)
    assert rep["completed"] == len(reqs) and rep["violations"] == 0
    retracts = [ev for ev in tr.events if ev["ev"] == "retract"]
    restores = [ev for ev in tr.events if ev["ev"] == "restore"]
    assert len(retracts) == e.stats.retractions
    assert len(restores) == len(retracts)  # all drained => all came back
    assert all(ev["via"] in ("swap", "recompute", "requeue")
               for ev in retracts + restores)
