"""Paged-attention kernel (attend straight from the block pool) vs the
block-table-native XLA mirror, the gather path, and the serve oracle — plus
the paged-scatter overflow regression.

Kernel variants run in interpret mode (kernel body executed on CPU); the
``REPRO_PAGED_ATTN`` env flips the engine-facing lowering per test.

(Multi-device setup comes from tests/conftest.py — pytest-only module.)"""
import dataclasses  # noqa: E402
import os  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs import ASSIGNED_ARCHS  # noqa: E402
from repro.kernels import ops, paged_attention as pa, ref  # noqa: E402
from repro.models import blocks  # noqa: E402
from repro.models.layers import ModelOptions  # noqa: E402
from repro.serve import Request, ServeEngine  # noqa: E402

RNG = np.random.default_rng(7)


def make_paged_case(b, sq, hq, hkv, hd, nb, bs, n_tbl, kv_lens, dt):
    """Random pool + ragged per-row tables. Each row r holds ``kv_lens[r]``
    live tokens (the new sq arrive at the end); live blocks are a random
    disjoint subset of the pool, remaining table entries are -1."""
    q = jnp.asarray(RNG.normal(size=(b, sq, hq, hd)), dt)
    k_pool = jnp.asarray(RNG.normal(size=(nb, bs, hkv, hd)), dt)
    v_pool = jnp.asarray(RNG.normal(size=(nb, bs, hkv, hd)), dt)
    tables = np.full((b, n_tbl), -1, np.int32)
    free = list(RNG.permutation(nb))
    for r, ln in enumerate(kv_lens):
        need = -(-max(ln, 1) // bs)
        for j in range(need):
            tables[r, j] = free.pop()
    kv_len = jnp.asarray(kv_lens, jnp.int32)
    kv_offset = kv_len - sq  # the sq new tokens sit at the row's tail
    return q, k_pool, v_pool, jnp.asarray(tables), kv_offset, kv_len


SWEEP = [
    # b, sq, hq, hkv, hd, nb, bs, n_tbl, kv_lens, window, dtype
    (2, 1, 4, 2, 16, 12, 4, 4, [9, 16], 0, jnp.float32),       # decode GQA
    (2, 1, 4, 4, 16, 12, 4, 4, [1, 13], 0, jnp.float32),       # MHA ragged
    (3, 1, 8, 2, 16, 16, 8, 3, [24, 5, 17], 0, jnp.float32),   # g=4, bs=8
    (2, 1, 4, 2, 16, 12, 4, 4, [9, 16], 3, jnp.float32),       # window
    (2, 4, 4, 2, 16, 14, 4, 5, [11, 20], 0, jnp.float32),      # append
    (2, 5, 4, 2, 16, 14, 4, 6, [5, 21], 5, jnp.float32),       # append+win
    (2, 1, 4, 2, 16, 12, 16, 2, [9, 30], 0, jnp.bfloat16),     # bf16, bs=16
    (2, 3, 2, 2, 32, 10, 8, 3, [19, 8], 0, jnp.bfloat16),      # bf16 append
]


@pytest.mark.parametrize("variant", ["loop", "blockspec"])
@pytest.mark.parametrize("b,sq,hq,hkv,hd,nb,bs,n_tbl,kv_lens,window,dt",
                         SWEEP)
def test_kernel_vs_ref(variant, b, sq, hq, hkv, hd, nb, bs, n_tbl, kv_lens,
                       window, dt):
    case = make_paged_case(b, sq, hq, hkv, hd, nb, bs, n_tbl, kv_lens, dt)
    q, k_pool, v_pool, tables, kv_offset, kv_len = case
    r = ref.paged_attention_ref(q, k_pool, v_pool, tables, kv_offset, kv_len,
                                causal=True, window=window)
    o = pa.paged_attention_pool(q, k_pool, v_pool, tables, kv_offset, kv_len,
                                causal=True, window=window, interpret=True,
                                variant=variant)
    tol = 3e-2 if dt == jnp.bfloat16 else 2e-5
    err = float(jnp.max(jnp.abs(r.astype(jnp.float32)
                                - o.astype(jnp.float32))))
    assert err < tol, err


@pytest.mark.parametrize("variant", ["loop", "blockspec"])
@pytest.mark.parametrize("q_lens", [[4, 1], [3, 0], [1, 4]])
def test_kernel_ragged_q_lens(variant, q_lens):
    """Mixed-tick waves: rows carry ragged per-row query counts (chunk
    width prefilling, 1 decoding, 0 idle) padded to the wave max. Padded
    query positions must come out exactly zero and real positions must
    match the reference attending only kv_offset + q_len_r tokens."""
    b, sq, hq, hkv, hd, nb, bs, n_tbl = 2, 4, 4, 2, 16, 14, 4, 5
    kv_off = [7, 9]
    kv_lens = [o + q for o, q in zip(kv_off, q_lens)]
    # capacity must cover each row's real tokens; build at the padded tail
    case = make_paged_case(b, sq, hq, hkv, hd, nb, bs, n_tbl,
                           [o + sq for o in kv_off], jnp.float32)
    q, k_pool, v_pool, tables, _, _ = case
    kv_offset = jnp.asarray(kv_off, jnp.int32)
    kv_len = jnp.asarray(kv_lens, jnp.int32)
    ql = jnp.asarray(q_lens, jnp.int32)
    r = ref.paged_attention_ref(q, k_pool, v_pool, tables, kv_offset, kv_len,
                                causal=True, window=0, q_lens=ql)
    o = pa.paged_attention_pool(q, k_pool, v_pool, tables, kv_offset, kv_len,
                                causal=True, window=0, interpret=True,
                                variant=variant, q_lens=ql)
    err = float(jnp.max(jnp.abs(r - o)))
    assert err < 2e-5, err
    # padded rows really are zeros (a fully-masked row never contributes)
    on = np.asarray(o)
    for row, n in enumerate(q_lens):
        np.testing.assert_array_equal(on[row, n:], 0.0)


def test_kernel_vs_gathered_dense():
    """The pool path must equal plain masked attention over each row's
    gathered logical view — the end-to-end gather-path equivalence."""
    from repro.models.layers import attention
    b, sq, hq, hkv, hd, nb, bs, n_tbl = 2, 1, 4, 2, 16, 12, 4, 4
    kv_lens = [9, 15]
    case = make_paged_case(b, sq, hq, hkv, hd, nb, bs, n_tbl, kv_lens,
                           jnp.float32)
    q, k_pool, v_pool, tables, kv_offset, kv_len = case
    span = (jnp.clip(tables, 0, nb - 1)[:, :, None] * bs
            + jnp.arange(bs)[None, None, :]).reshape(b, n_tbl * bs)
    k_rows = jnp.take(k_pool.reshape(nb * bs, hkv, hd), span, axis=0)
    v_rows = jnp.take(v_pool.reshape(nb * bs, hkv, hd), span, axis=0)
    want = attention(q, k_rows, v_rows, causal=False, window=0,
                     kv_offset=0, kv_len=kv_len, opts=ModelOptions())
    for variant in ("loop", "blockspec"):
        got = pa.paged_attention_pool(q, k_pool, v_pool, tables, kv_offset,
                                      kv_len, causal=True, window=0,
                                      interpret=True, variant=variant)
        np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                                   atol=2e-5)


def test_scatter_overflow_leaves_last_block_untouched():
    """Regression: tokens past table capacity must be DROPPED. Clipping the
    block index routed them into the row's last allocated block (a valid
    physical id passes the ``phys >= 0`` check) and silently overwrote its
    cached K/V."""
    nb, bs, hkv, hd, n_tbl = 4, 4, 2, 8, 2  # capacity 2 blocks = 8 tokens
    cache = {
        "k": jnp.asarray(RNG.normal(size=(nb, bs, hkv, hd)), jnp.float32),
        "v": jnp.asarray(RNG.normal(size=(nb, bs, hkv, hd)), jnp.float32),
    }
    tables = jnp.asarray([[2, 1]], jnp.int32)  # full table, last block = 1
    k = jnp.asarray(RNG.normal(size=(1, 1, hkv, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 1, hkv, hd)), jnp.float32)
    # row sits AT capacity: the write would land at pos 8 -> block index 2,
    # one past the table; clipped-to-last it would corrupt block 1 slot 0
    new = blocks.paged_kv_scatter(cache, k, v, tables,
                                  jnp.asarray([8], jnp.int32))
    np.testing.assert_array_equal(np.asarray(new["k"]),
                                  np.asarray(cache["k"]))
    np.testing.assert_array_equal(np.asarray(new["v"]),
                                  np.asarray(cache["v"]))
    # in-capacity writes still land: pos 5 -> block 1 slot 1
    new = blocks.paged_kv_scatter(cache, k, v, tables,
                                  jnp.asarray([5], jnp.int32))
    np.testing.assert_array_equal(np.asarray(new["k"][1, 1]),
                                  np.asarray(k[0, 0]))
    assert not np.array_equal(np.asarray(new["k"]), np.asarray(cache["k"]))


def _attn_case(cfg, mode, s, kv_lens, window=0):
    nb, bs, n_tbl = 16, 4, 6
    d = cfg.d_model
    p = {w: jnp.asarray(RNG.normal(size=(d, cfg.n_heads * cfg.head_dim))
                        / np.sqrt(d), jnp.float32) for w in ("wq", "wo")}
    for w in ("wk", "wv"):
        p[w] = jnp.asarray(RNG.normal(size=(d, cfg.n_kv_heads * cfg.head_dim))
                           / np.sqrt(d), jnp.float32)
    b = len(kv_lens)
    x = jnp.asarray(RNG.normal(size=(b, s, d)), jnp.float32)
    cache = {
        "k": jnp.asarray(RNG.normal(size=(nb, bs, cfg.n_kv_heads,
                                          cfg.head_dim)), jnp.float32),
        "v": jnp.asarray(RNG.normal(size=(nb, bs, cfg.n_kv_heads,
                                          cfg.head_dim)), jnp.float32),
    }
    tables = np.full((b, n_tbl), -1, np.int32)
    free = list(RNG.permutation(nb))
    for r, ln in enumerate(kv_lens):
        for j in range(-(-(ln + s) // bs)):
            tables[r, j] = free.pop()
    kv_offset = jnp.asarray(kv_lens, jnp.int32)
    pos = kv_offset[:, None] + jnp.arange(s)[None, :]
    return dict(p=p, x=x, pos=pos, cache=cache, kv_offset=kv_offset,
                mode=mode, window=window,
                block_tables=jnp.asarray(tables))


@pytest.mark.parametrize("mode,s,kv_lens,window", [
    ("decode", 1, [7, 12], 0),
    ("decode", 1, [7, 12], 3),
    ("append", 4, [5, 9], 0),
])
def test_attn_apply_kernel_matches_gather(monkeypatch, mode, s, kv_lens,
                                          window):
    """blocks.attn_apply with use_paged_kernel must match the gather path
    bit-for-bit on out AND cache, under both engine lowerings."""
    cfg = ASSIGNED_ARCHS["chatglm3-6b"].reduced()
    case = _attn_case(cfg, mode, s, kv_lens, window)
    kw = dict(case)
    p, x, pos = kw.pop("p"), kw.pop("x"), kw.pop("pos")
    out_g, cache_g = blocks.attn_apply(cfg, ModelOptions(), p, x, pos=pos,
                                       **kw)
    opts_k = ModelOptions(use_paged_kernel=True)
    for lowering in ("jnp", "interpret"):
        monkeypatch.setenv("REPRO_PAGED_ATTN", lowering)
        ops.paged_attention.clear_cache()  # env is read at trace time
        out_k, cache_k = blocks.attn_apply(cfg, opts_k, p, x, pos=pos, **kw)
        err = float(jnp.max(jnp.abs(out_g - out_k)))
        assert err < 2e-5, (lowering, err)
        for leaf in ("k", "v"):
            np.testing.assert_array_equal(np.asarray(cache_g[leaf]),
                                          np.asarray(cache_k[leaf]),
                                          err_msg=f"{lowering}/{leaf}")
    ops.paged_attention.clear_cache()


def _engine_build(**over):
    from repro.core import pipeline as pl
    from repro.core.partitioner import plan_stages
    from repro.launch.mesh import make_test_mesh
    cfg = ASSIGNED_ARCHS["chatglm3-6b"].reduced()
    mesh = make_test_mesh(1, 2)
    eng = pl.EngineConfig(n_trials=1, n_microbatches=2, microbatch=2,
                          n_stages=2, data_size=1, max_seq=24,
                          cache_dtype=jnp.float32, prefill_chunks=2,
                          paged=True, block_size=4, n_blocks=24, **over)
    plan = plan_stages(cfg, eng.n_stages)
    params = pl.init_trial_params(cfg, eng, plan, jax.random.PRNGKey(0),
                                  max_pos=24)
    return cfg, mesh, eng, params


@pytest.mark.parametrize("lowering", ["jnp", "interpret"])
def test_engine_kernel_matches_gather_and_oracle(monkeypatch, lowering):
    """Full serve engine: the kernel path's greedy tokens must be
    bit-identical to the gather path and the single-device oracle."""
    from test_serve_engine import oracle_tokens
    monkeypatch.setenv("REPRO_PAGED_ATTN", lowering)
    ops.paged_attention.clear_cache()
    cfg, mesh, eng, params = _engine_build()
    rng = np.random.default_rng(3)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, (p,)).astype(np.int32),
                    g, arrival=0.5 * i)
            for i, (p, g) in enumerate([(9, 4), (12, 3), (7, 5), (5, 2)])]
    e_g = ServeEngine(cfg, eng, mesh, params, ModelOptions())
    comp_g = e_g.run([r.clone() for r in reqs])
    e_k = ServeEngine(cfg, eng, mesh, params,
                      ModelOptions(use_paged_kernel=True))
    comp_k = e_k.run([r.clone() for r in reqs])
    for r, a, b in zip(reqs, comp_g, comp_k):
        assert a.tokens == b.tokens, f"request {r.rid}: kernel != gather"
        assert b.tokens == oracle_tokens(cfg, ModelOptions(), params, r), \
            f"request {r.rid}: kernel diverged from the oracle"
    assert e_k.allocator.all_free()
    ops.paged_attention.clear_cache()


@pytest.mark.parametrize("lowering", ["jnp", "interpret"])
def test_engine_fused_kernel_matches_split(monkeypatch, lowering):
    """Fused mixed-tick admission through the paged-attention kernel: the
    per-row q-length masking must keep greedy tokens and tick latencies
    bit-identical to the split schedule on the same lowering."""
    monkeypatch.setenv("REPRO_PAGED_ATTN", lowering)
    ops.paged_attention.clear_cache()
    cfg, mesh, eng, params = _engine_build()
    rng = np.random.default_rng(3)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, (p,)).astype(np.int32),
                    g, arrival=0.5 * i)
            for i, (p, g) in enumerate([(9, 4), (12, 3), (7, 5), (5, 2)])]
    opts = ModelOptions(use_paged_kernel=True)
    e_s = ServeEngine(cfg, eng, mesh, params, opts)
    comp_s = e_s.run([r.clone() for r in reqs])
    e_f = ServeEngine(cfg, eng, mesh, params, opts, fused=True)
    comp_f = e_f.run([r.clone() for r in reqs])
    for a, b in zip(comp_s, comp_f):
        assert a.tokens == b.tokens, f"request {a.rid}: fused != split"
        assert a.ttft_ticks == b.ttft_ticks
        assert a.finished_tick == b.finished_tick
    assert e_f.stats.calls < e_s.stats.calls
    assert e_f.allocator.all_free()
    ops.paged_attention.clear_cache()


def test_engine_kernel_requires_paged():
    cfg, mesh, eng, params = _engine_build()
    dense = dataclasses.replace(eng, paged=False, n_blocks=0)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, dense, mesh, params,
                    ModelOptions(use_paged_kernel=True))


def test_paged_mode_default_and_env(monkeypatch):
    monkeypatch.delenv("REPRO_PAGED_ATTN", raising=False)
    assert ops._paged_mode() == ("jnp" if jax.default_backend() == "cpu"
                                 else "pallas")
    for m in ("pallas", "interpret", "jnp"):
        monkeypatch.setenv("REPRO_PAGED_ATTN", m)
        assert ops._paged_mode() == m
