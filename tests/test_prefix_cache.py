"""Radix prefix cache: allocator refcounts, radix match/insert/LRU-eviction,
copy-on-write forking of shared tail blocks, hit-aware batcher admission —
plus engine-level greedy parity (cache on == cache off == oracle, including
under CoW forks) and eviction under pool pressure with per-arch fairness.

Host-side sections run in milliseconds; the engine sections compile the
pipelined serve steps (multi-device setup from tests/conftest.py).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS
from repro.core import pipeline as pl
from repro.core.partitioner import plan_stages
from repro.launch.mesh import make_test_mesh
from repro.models import lm
from repro.models.layers import ModelOptions
from repro.serve import (Batcher, BlockAllocator, BlockTable, PrefixCache,
                         Request, ServeEngine)

MAX_SEQ = 24


# ---------------------------------------------------------------------------
# BlockAllocator refcounts
# ---------------------------------------------------------------------------


def test_refcounts_share_and_release():
    a = BlockAllocator(n_blocks=6, block_size=4)
    ids = a.alloc(2)
    assert [a.ref_count(i) for i in ids] == [1, 1]
    a.incref(ids)  # a second reader (prefix sharing)
    assert a.decref(ids) == []  # still live under the first reference
    assert a.used_blocks() == 2
    assert a.decref(ids) == ids  # last reference: back to the free list
    assert a.all_free()
    with pytest.raises(ValueError):
        a.decref([ids[0]])  # double free still rejected
    with pytest.raises(ValueError):
        a.incref([ids[0]])  # incref of a free block is a bug


def test_shared_block_never_rehanded_out():
    a = BlockAllocator(n_blocks=2, block_size=4)
    ids = a.alloc(2)
    a.incref([ids[0]])
    a.decref(ids)  # ids[1] freed, ids[0] still referenced
    assert a.alloc(2) is None  # only one block is actually free
    assert a.alloc(1) == [ids[1]]


# ---------------------------------------------------------------------------
# Radix tree: match / insert / LRU eviction
# ---------------------------------------------------------------------------


def _prompt(tokens):
    return np.asarray(tokens, np.int32)


def _cache_prompt(alloc, pc, prompt, partition=0):
    """Run one request's life host-side: alloc blocks, insert, release."""
    t = BlockTable(alloc, partition, cache=pc)
    assert t.ensure(int(prompt.shape[0]))
    pc.insert(partition, prompt, t.blocks)
    t.close()
    return t


def test_match_full_blocks_and_partial_tail():
    alloc = BlockAllocator(16, 4)
    pc = PrefixCache(alloc)
    _cache_prompt(alloc, pc, _prompt(range(14)))  # 3 full blocks cached
    assert pc.cached_blocks() == 3
    # 10 shared tokens: 2 full blocks + 2 tokens into the third
    hit = pc.match(0, _prompt(list(range(10)) + [99, 98]))
    assert hit.n_full_blocks == 2 and hit.tail_tokens == 2
    assert hit.hit_tokens == 10 and len(hit.block_ids) == 3
    # no hit for a diverging prompt
    assert pc.match(0, _prompt([55] * 12)).hit_tokens == 0


def test_match_capped_below_prompt_len():
    """A fully cached prompt must still leave >= 1 token to prefill (the
    head emits the first token from the final prompt position)."""
    alloc = BlockAllocator(16, 4)
    pc = PrefixCache(alloc)
    _cache_prompt(alloc, pc, _prompt(range(12)))
    hit = pc.match(0, _prompt(range(12)))  # identical, block-aligned
    assert hit.hit_tokens == 11  # 2 full blocks + 3 of the last
    assert hit.n_full_blocks == 2 and hit.tail_tokens == 3


def test_insert_dedupes_existing_chunks():
    alloc = BlockAllocator(16, 4)
    pc = PrefixCache(alloc)
    _cache_prompt(alloc, pc, _prompt(range(8)))
    used = alloc.used_blocks()
    # a second identical prompt adopts nothing: its blocks drop with it
    _cache_prompt(alloc, pc, _prompt(range(8)))
    assert alloc.used_blocks() == used and pc.cached_blocks() == 2


def test_lru_eviction_leaf_first_and_pinned_blocks_skipped():
    alloc = BlockAllocator(8, 4)
    pc = PrefixCache(alloc)
    _cache_prompt(alloc, pc, _prompt(range(8)))        # chain A: 2 blocks
    _cache_prompt(alloc, pc, _prompt([50 + i for i in range(8)]))  # chain B
    assert alloc.used_blocks() == 4 and alloc.free_blocks() == 4
    # pin chain A via a live hit so chain B's leaf is the LRU victim
    hit = pc.match(0, _prompt(list(range(8)) + [1]))
    pc.acquire(hit)
    # drain the pool, then ask for one more: B's leaf must go first
    alloc.alloc(4)
    t = BlockTable(alloc, cache=pc)
    assert t.ensure(4)
    assert pc.evictions == 1
    assert pc.match(0, _prompt([50 + i for i in range(8)] + [1])).hit_tokens \
        == 4  # B's root block survives, its leaf is gone
    # chain A is pinned by the live hit (refcount 2): not evictable — only
    # B's root can go, which is one short of the two blocks needed
    t2 = BlockTable(alloc, cache=pc)
    assert not t2.ensure(8)
    assert pc.match(0, _prompt(list(range(8)) + [1])).hit_tokens == 8


# ---------------------------------------------------------------------------
# Copy-on-write forking
# ---------------------------------------------------------------------------


def test_fork_shared_replaces_only_shared_blocks():
    alloc = BlockAllocator(16, 4)
    pc = PrefixCache(alloc)
    _cache_prompt(alloc, pc, _prompt(range(14)))
    hit = pc.match(0, _prompt(list(range(10)) + [99, 98]))
    pc.acquire(hit)
    t = BlockTable(alloc, cache=pc)
    t.seed(hit.block_ids)
    assert t.ensure(12)
    shared_tail = t.blocks[2]
    assert alloc.ref_count(shared_tail) == 2  # tree + this table
    # writing tokens [10, 12) overlaps only the tail block
    pairs = t.fork_shared(10, 12)
    assert len(pairs) == 1 and pairs[0][0] == shared_tail
    assert t.blocks[2] == pairs[0][1] != shared_tail
    assert alloc.ref_count(shared_tail) == 1  # back to tree-only
    assert alloc.ref_count(t.blocks[2]) == 1  # private to the writer
    # full-hit blocks stay shared and untouched
    assert t.blocks[:2] == [n.block for n in hit.nodes]
    assert t.fork_shared(12, 16) == []  # nothing shared in later ranges
    t.close()


def test_fork_shared_is_atomic_under_exhaustion():
    alloc = BlockAllocator(4, 4)
    pc = PrefixCache(alloc)
    _cache_prompt(alloc, pc, _prompt(range(8)))
    hit = pc.match(0, _prompt(list(range(6)) + [9]))
    assert hit.n_full_blocks == 1 and hit.tail_tokens == 2
    pc.acquire(hit)
    t = BlockTable(alloc, cache=pc)
    t.seed(hit.block_ids)
    # drain the pool so the fork cannot allocate (cached blocks are pinned)
    held = alloc.alloc(2)
    assert t.fork_shared(6, 7) is None  # stall signal...
    assert alloc.ref_count(hit.tail.block) == 2  # ...and nothing changed
    alloc.decref(held)
    assert len(t.fork_shared(6, 7)) == 1  # retry succeeds
    t.close()


# ---------------------------------------------------------------------------
# Batcher admission with the prefix cache
# ---------------------------------------------------------------------------


def _req(rid, prompt, gen=3, arrival=0.0, arch=0):
    return Request(rid, _prompt(prompt), gen, arrival=arrival, arch=arch)


def test_admission_commits_only_non_cached_need():
    alloc = BlockAllocator(16, 4)
    pc = PrefixCache(alloc)
    b = Batcher(n_microbatches=2, mb_global=2, prefill_chunks=2, max_seq=32,
                allocator=alloc, prefix_cache=pc)
    _cache_prompt(alloc, pc, _prompt(range(12)))
    assert b.admit(1.0) == []  # nothing queued yet
    # 10 cached of 12 prompt tokens; total 14 -> 4 blocks, 2 full cached
    b.enqueue(_req(0, list(range(10)) + [77, 66]))
    slot = b.admit(1.0)[0]
    assert slot.hit_tokens == 10 and slot.pos == 10
    assert slot.block_commit == 2  # 4 total - 2 full cached
    assert len(slot.cached_ids) == 3  # 2 full + shared tail
    assert sum(c.shape[0] for c in slot.chunks) == 2  # suffix only
    # referenced cached blocks charge the partition once
    assert b.committed_blocks(0) == 2 + 3
    # a second sharer adds only its own commit (cached ids already pinned)
    b.enqueue(_req(1, list(range(10)) + [11, 22]))
    slot2 = b.admit(2.0)[0]
    assert slot2.hit_tokens == 10
    assert b.committed_blocks(0) == 2 + 2 + 3


def test_admission_defers_when_pinned_cache_exceeds_pool():
    """Cached blocks a request would pin count against the partition: a hit
    does not let the committed total overrun the pool."""
    alloc = BlockAllocator(4, 4)
    pc = PrefixCache(alloc)
    b = Batcher(n_microbatches=2, mb_global=1, prefill_chunks=1, max_seq=16,
                allocator=alloc, prefix_cache=pc)
    _cache_prompt(alloc, pc, _prompt(range(8)))  # 2 cached blocks
    b.enqueue(_req(0, list(range(8)) + [3, 4], gen=5))  # 14 tok -> 4 blocks
    slot = b.admit(1.0)[0]
    # 2 new + 2 pinned cached = 4 = full partition
    assert b.committed_blocks(0) == 4
    b.enqueue(_req(1, [91, 92, 93, 94], gen=2))  # 2 more blocks: no room
    assert b.admit(2.0) == []
    slot.release()
    assert [s.request.rid for s in b.admit(3.0)] == [1]


def test_prefix_pressure_preserves_per_arch_fairness():
    """Arch 0's partition full of pinned cached prefixes defers only arch 0;
    arch 1 keeps admitting into its own partition (the PR-4 guarantee must
    survive blocks that outlive their requests)."""
    alloc = BlockAllocator(8, 4, n_partitions=2)
    pc = PrefixCache(alloc)
    b = Batcher(n_microbatches=2, mb_global=1, prefill_chunks=1, max_seq=16,
                n_trials=2, allocator=alloc, prefix_cache=pc)
    _cache_prompt(alloc, pc, _prompt(range(8)), partition=0)
    # arch 0: hits 8 tokens, pins 2 cached + commits 1 new = 3 of 4
    b.enqueue(_req(0, list(range(8)) + [1, 2], gen=3, arch=0))
    # arch 0 second request (2 blocks): deferred, 3 + 2 > 4
    b.enqueue(_req(1, [71, 72, 73, 74], gen=2, arch=0))
    # arch 1: unaffected by arch 0's cached blocks
    b.enqueue(_req(2, [81, 82, 83, 84], gen=2, arch=1))
    admitted = b.admit(1.0)
    by_arch = {k: [s.request.rid for s in admitted if s.k == k]
               for k in (0, 1)}
    assert by_arch[0] == [0] and by_arch[1] == [2]


# ---------------------------------------------------------------------------
# Engine: greedy parity + eviction under pressure (device side)
# ---------------------------------------------------------------------------


def build(n_stages=2, data_size=1, slots=2, microbatch=2, n_trials=1,
          block_size=4, n_blocks=24):
    cfg = ASSIGNED_ARCHS["chatglm3-6b"].reduced()
    opts = ModelOptions()
    mesh = make_test_mesh(data_size, n_stages)
    eng = pl.EngineConfig(n_trials=n_trials, n_microbatches=slots,
                          microbatch=microbatch, n_stages=n_stages,
                          data_size=data_size, max_seq=MAX_SEQ,
                          cache_dtype=jnp.float32, prefill_chunks=2,
                          paged=True, block_size=block_size,
                          n_blocks=n_blocks)
    plan = plan_stages(cfg, eng.n_stages)
    params = pl.init_trial_params(cfg, eng, plan, jax.random.PRNGKey(0),
                                  max_pos=MAX_SEQ)
    return cfg, opts, mesh, eng, params


def oracle_tokens(cfg, opts, params, req, k=0):
    """Single-device greedy reference against trial k's weights."""
    p1 = jax.tree.map(lambda x: x[k], params)
    vpad = p1["embed"]["tok"].shape[0]
    if vpad != cfg.vocab_size:
        p1["embed"]["tok"] = p1["embed"]["tok"][:cfg.vocab_size]
        if "head" in p1:
            p1["head"] = p1["head"][:, :cfg.vocab_size]
    n_stack = jax.tree.leaves(p1["layers"])[0].shape[0]
    cache = lm.init_cache(cfg, 1, MAX_SEQ, cache_dtype=jnp.float32,
                          n_layers=n_stack)
    logits, cache, _ = lm.forward(cfg, opts, p1,
                                  {"tokens": jnp.asarray(req.prompt[None])},
                                  mode="prefill", cache=cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    for t in range(req.max_new_tokens - 1):
        logits, cache, _ = lm.forward(
            cfg, opts, p1, {"tokens": jnp.asarray([[toks[-1]]], jnp.int32)},
            mode="decode", cache=cache,
            kv_offset=jnp.asarray([req.prompt_len + t], jnp.int32))
        toks.append(int(jnp.argmax(logits[0, 0])))
    return toks


def shared_prefix_trace(vocab, seed=0, n_arches=1):
    """A warm-up request per arch followed by sharers whose prompts reuse a
    10-token prefix (2 full blocks + a partial tail at block_size 4, so the
    hits exercise both full-block reuse and the CoW fork) and one cold
    request; sharers arrive after the warm-up has surely completed."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab, (10,)).astype(np.int32)
    reqs = []
    rid = 0
    for arch in range(n_arches):
        for sl, arrival, gen in ((2, 0.0, 4), (2, 40.0, 4), (4, 41.0, 3),
                                 (6, 42.0, 4)):
            sfx = rng.integers(0, vocab, (sl,)).astype(np.int32)
            reqs.append(Request(rid, np.concatenate([shared, sfx]), gen,
                                arrival=arrival, arch=arch))
            rid += 1
        reqs.append(Request(rid, rng.integers(0, vocab, (9,)).astype(np.int32),
                            3, arrival=43.0, arch=arch))
        rid += 1
    return reqs


def _clone(reqs):
    return [r.clone() for r in reqs]


def test_engine_prefix_cache_matches_nocache_and_oracle():
    """The acceptance bar: greedy tokens are bit-identical with the prefix
    cache on vs off (and vs the single-device oracle), including under CoW
    forks of a shared tail block."""
    cfg, opts, mesh, eng, params = build()
    reqs = shared_prefix_trace(cfg.vocab_size)
    e_off = ServeEngine(cfg, eng, mesh, params, opts)
    c_off = e_off.run(_clone(reqs))
    e_on = ServeEngine(cfg, eng, mesh, params, opts, prefix_cache=True)
    c_on = e_on.run(_clone(reqs))
    for r, a, b in zip(reqs, c_off, c_on):
        assert a.tokens == b.tokens, f"request {r.rid}: cache-on != cache-off"
        assert b.tokens == oracle_tokens(cfg, opts, params, r), \
            f"request {r.rid}: prefix-cached engine diverged from the oracle"
    # the cache actually worked: hits landed, a shared tail was CoW-forked,
    # and whole prefill waves were skipped
    s = e_on.stats
    assert s.prefix_hits >= 3 and s.prefix_hit_tokens >= 30
    assert s.cow_forks >= 1
    assert s.prefill_calls < e_off.stats.prefill_calls
    # completed prompts stay cached (tree references), not freed
    assert e_on.prefix_cache.cached_blocks() > 0
    assert e_on.allocator.used_blocks() == e_on.prefix_cache.cached_blocks()


def test_engine_eviction_under_pressure_no_deadlock():
    """Fill the pool with cached prefixes, then admit fresh requests: LRU
    leaves must be reclaimed on demand with no deadlock and every request
    served (the cache can never wedge admission)."""
    cfg, opts, mesh, eng, params = build(n_blocks=8)  # 8 x 4 = 32 cache rows
    rng = np.random.default_rng(3)
    reqs = []
    # phase 1: four distinct prompts whose cached blocks fill most of the
    # pool after completion (each caches 2 full blocks)
    for i in range(4):
        reqs.append(Request(i, rng.integers(0, cfg.vocab_size,
                                            (9,)).astype(np.int32),
                            2, arrival=float(10 * i)))
    # phase 2: fresh prompts needing allocation -> evictions
    for i in range(4, 8):
        reqs.append(Request(i, rng.integers(0, cfg.vocab_size,
                                            (9,)).astype(np.int32),
                            2, arrival=float(60 + 10 * (i - 4))))
    e = ServeEngine(cfg, eng, mesh, params, opts, prefix_cache=True)
    comps = e.run(_clone(reqs), max_ticks=2000)
    assert [c.rid for c in comps] == list(range(8))
    for r, c in zip(reqs, comps):
        assert c.tokens == oracle_tokens(cfg, opts, params, r), \
            f"request {r.rid} diverged under eviction pressure"
    assert e.stats.prefix_evictions > 0
    # invariant: everything still live is exactly the tree's holdings
    assert e.allocator.used_blocks() == e.prefix_cache.cached_blocks()


@pytest.mark.slow
def test_engine_multiarch_sharded_prefix_parity():
    """K=2 gang x data_size=2 (four pool partitions, per-partition radix
    trees): prefix hits and CoW forks must preserve bit-exactness against
    the cache-off gang."""
    cfg, opts, mesh, eng, params = build(data_size=2, slots=1, microbatch=1,
                                         n_trials=2)
    reqs = shared_prefix_trace(cfg.vocab_size, seed=5, n_arches=2)
    e_off = ServeEngine(cfg, eng, mesh, params, opts)
    c_off = e_off.run(_clone(reqs))
    e_on = ServeEngine(cfg, eng, mesh, params, opts, prefix_cache=True)
    c_on = e_on.run(_clone(reqs))
    for a, b in zip(c_off, c_on):
        assert a.tokens == b.tokens, \
            f"request {a.rid} (arch {a.arch}): cache-on != cache-off"
    assert e_on.allocator.n_partitions == 4
    assert e_on.stats.prefix_hits >= 2


def test_engine_rejects_prefix_cache_without_paging():
    cfg, opts, mesh, eng, params = build()
    dense = dataclasses.replace(eng, paged=False, n_blocks=0)
    with pytest.raises(ValueError):
        ServeEngine(cfg, dense, mesh, params, opts, prefix_cache=True)
