"""Continuous-batching serve engine: per-request greedy exactness vs the
static-batch reference, slot recycling (occupancy beats lockstep batching on
a staggered trace), paged-KV parity with the dense path, multi-arch
co-serving (routing, per-arch backpressure, gang-vs-single-arch parity),
sliding-window parity with a windowed oracle, admission policies, latency
metrics, and clean termination of a drained queue.

(Multi-device setup comes from tests/conftest.py — pytest-only module.)"""
import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs import ASSIGNED_ARCHS  # noqa: E402
from repro.core import pipeline as pl  # noqa: E402
from repro.core.partitioner import plan_stages  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models.layers import ModelOptions  # noqa: E402
from repro.serve import (Batcher, BlockAllocator, Request,  # noqa: E402
                         ServeEngine, poisson_trace, static_serve)

MAX_SEQ = 24


def build(arch, n_stages=2, data_size=1, slots=2, microbatch=2,
          prefill_chunks=2, n_trials=1, window=0):
    cfg = ASSIGNED_ARCHS[arch].reduced()
    opts = ModelOptions()
    mesh = make_test_mesh(data_size, n_stages)
    eng = pl.EngineConfig(n_trials=n_trials, n_microbatches=slots,
                          microbatch=microbatch, n_stages=n_stages,
                          data_size=data_size, max_seq=MAX_SEQ,
                          cache_dtype=jnp.float32,
                          prefill_chunks=prefill_chunks, window=window)
    plan = plan_stages(cfg, eng.n_stages)
    params = pl.init_trial_params(cfg, eng, plan, jax.random.PRNGKey(0),
                                  max_pos=MAX_SEQ)
    return cfg, opts, mesh, eng, params


def oracle_tokens(cfg, opts, params, req, k=0, window=0):
    """Single-device greedy reference for one request against trial k's
    weights (the co-serving gang stacks one variant per trial row)."""
    p1 = jax.tree.map(lambda x: x[k], params)
    vpad = p1["embed"]["tok"].shape[0]
    if vpad != cfg.vocab_size:
        p1["embed"]["tok"] = p1["embed"]["tok"][:cfg.vocab_size]
        if "head" in p1:
            p1["head"] = p1["head"][:, :cfg.vocab_size]
    # cache must match the stage-padded layer stack (lm.forward masks pads)
    n_stack = jax.tree.leaves(p1["layers"])[0].shape[0]
    cache = lm.init_cache(cfg, 1, MAX_SEQ, cache_dtype=jnp.float32,
                          n_layers=n_stack)
    logits, cache, _ = lm.forward(cfg, opts, p1,
                                  {"tokens": jnp.asarray(req.prompt[None])},
                                  mode="prefill", cache=cache, window=window)
    toks = [int(jnp.argmax(logits[0, -1]))]
    for t in range(req.max_new_tokens - 1):
        logits, cache, _ = lm.forward(
            cfg, opts, p1, {"tokens": jnp.asarray([[toks[-1]]], jnp.int32)},
            mode="decode", cache=cache, window=window,
            kv_offset=jnp.asarray([req.prompt_len + t], jnp.int32))
        toks.append(int(jnp.argmax(logits[0, 0])))
    return toks


def staggered_trace(vocab, seed=1, n_arches=1):
    """Heterogeneous prompt/gen lengths + staggered arrivals: the workload
    static batching cannot pack. ``n_arches`` > 1 round-robins the target
    model variant (a mixed co-serving stream)."""
    rng = np.random.default_rng(seed)
    shapes = [(9, 4), (12, 3), (7, 5), (12, 6), (5, 2), (9, 4), (7, 3)]
    return [Request(i, rng.integers(0, vocab, (p,)).astype(np.int32), g,
                    arrival=0.5 * i, arch=i % n_arches)
            for i, (p, g) in enumerate(shapes)]


@pytest.mark.parametrize("arch", ["chatglm3-6b"])
def test_continuous_matches_oracle_per_request(arch):
    cfg, opts, mesh, eng, params = build(arch)
    reqs = staggered_trace(cfg.vocab_size)
    engine = ServeEngine(cfg, eng, mesh, params, opts)
    comps = engine.run([r.clone() for r in reqs])
    assert [c.rid for c in comps] == [r.rid for r in reqs]
    for r, c in zip(reqs, comps):
        assert len(c.tokens) == r.max_new_tokens
        assert c.tokens == oracle_tokens(cfg, opts, params, r), \
            f"request {r.rid} diverged from the single-device reference"


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-7b"])
def test_continuous_matches_oracle_ssm_hybrid(arch):
    """Recurrent-state families: slot reset + chunked admission must restart
    SSM/conv states exactly (recycled rows would otherwise leak state)."""
    cfg, opts, mesh, eng, params = build(arch)
    reqs = staggered_trace(cfg.vocab_size, seed=2)
    engine = ServeEngine(cfg, eng, mesh, params, opts)
    comps = engine.run([r.clone() for r in reqs])
    for r, c in zip(reqs, comps):
        assert c.tokens == oracle_tokens(cfg, opts, params, r), \
            f"request {r.rid} diverged from the single-device reference"


def test_continuous_beats_static_occupancy_and_matches_tokens():
    """On a staggered-generation trace, recycling slots keeps occupancy above
    the lockstep baseline — and both paths emit identical greedy tokens."""
    cfg, opts, mesh, eng, params = build("chatglm3-6b", slots=2, microbatch=2)
    rng = np.random.default_rng(0)
    plen = 8
    gens = [2, 7, 3, 6, 2, 5, 4, 7, 2, 6, 3, 5]  # staggered budgets
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    (plen,)).astype(np.int32), g)
            for i, g in enumerate(gens)]

    engine = ServeEngine(cfg, eng, mesh, params, opts)
    cont = engine.run([r.clone() for r in reqs])
    stat, sstats = static_serve(cfg, eng, mesh, params, reqs, opts)

    for a, b in zip(cont, stat):
        assert a.tokens == b.tokens, f"request {a.rid}: continuous != static"
    cstats = engine.stats
    assert cstats.slot_occupancy > sstats.slot_occupancy, (
        cstats.summary(), sstats.summary())
    assert cstats.decode_occupancy > sstats.decode_occupancy, (
        cstats.summary(), sstats.summary())


def _clone(reqs):
    return [r.clone() for r in reqs]


def test_paged_matches_dense_and_oracle():
    """Paged KV (shared block pool + block tables) must emit per-request
    greedy tokens bit-identical to the dense strips and the single-device
    oracle — and return every block to the free list on completion."""
    cfg, opts, mesh, eng, params = build("chatglm3-6b")
    paged = dataclasses.replace(eng, paged=True, block_size=4, n_blocks=24)
    reqs = staggered_trace(cfg.vocab_size)
    dense_engine = ServeEngine(cfg, eng, mesh, params, opts)
    comp_dense = dense_engine.run(_clone(reqs))
    paged_engine = ServeEngine(cfg, paged, mesh, params, opts)
    comp_paged = paged_engine.run(_clone(reqs))
    for r, a, b in zip(reqs, comp_dense, comp_paged):
        assert a.tokens == b.tokens, f"request {r.rid}: paged != dense"
        assert b.tokens == oracle_tokens(cfg, opts, params, r), \
            f"request {r.rid}: paged diverged from the oracle"
    assert paged_engine.allocator.all_free()  # free-on-completion, no leaks
    assert max(paged_engine.stats.block_usage_samples) <= paged.n_blocks


def test_paged_backpressure_still_exact():
    """A pool too small for the full grid defers admission (backpressure)
    but must not change any request's tokens or lose requests."""
    cfg, opts, mesh, eng, params = build("chatglm3-6b")
    # 6 blocks x 4 tokens = 24 cache tokens: roughly one long or two short
    # requests live at a time (staggered totals are 9..17 tokens)
    paged = dataclasses.replace(eng, paged=True, block_size=4, n_blocks=6)
    reqs = staggered_trace(cfg.vocab_size)
    dense_engine = ServeEngine(cfg, eng, mesh, params, opts)
    comp_dense = dense_engine.run(_clone(reqs))
    paged_engine = ServeEngine(cfg, paged, mesh, params, opts)
    comp_paged = paged_engine.run(_clone(reqs), max_ticks=2000)
    assert [c.rid for c in comp_paged] == [r.rid for r in reqs]
    for a, b in zip(comp_dense, comp_paged):
        assert a.tokens == b.tokens, f"request {a.rid}: paged != dense"
    # the pool bound concurrency below the cell count at least once
    assert max(paged_engine.stats.block_usage_samples) <= 6
    assert paged_engine.stats.peak_live < paged_engine.batcher.n_cells
    assert paged_engine.allocator.all_free()


@pytest.mark.slow
def test_paged_sharded_pool_matches_dense():
    """data_size=2: each shard owns a pool partition and tables carry local
    ids — exactness must survive the sharded scatter/gather."""
    cfg, opts, mesh, eng, params = build("chatglm3-6b", n_stages=2,
                                         data_size=2, microbatch=1)
    paged = dataclasses.replace(eng, paged=True, block_size=4, n_blocks=24)
    reqs = staggered_trace(cfg.vocab_size, seed=3)
    dense_engine = ServeEngine(cfg, eng, mesh, params, opts)
    comp_dense = dense_engine.run(_clone(reqs))
    paged_engine = ServeEngine(cfg, paged, mesh, params, opts)
    comp_paged = paged_engine.run(_clone(reqs))
    for a, b in zip(comp_dense, comp_paged):
        assert a.tokens == b.tokens, f"request {a.rid}: paged != dense"
    assert paged_engine.allocator.n_partitions == 2
    assert paged_engine.allocator.all_free()


def test_drained_queue_terminates():
    cfg, opts, mesh, eng, params = build("chatglm3-6b", prefill_chunks=3)
    reqs = poisson_trace(3, rate=0.4, vocab=cfg.vocab_size,
                         prompt_lens=(6,), gen_lens=(3,), seed=5)
    engine = ServeEngine(cfg, eng, mesh, params, opts)
    comps = engine.run(reqs, max_ticks=500)
    assert len(comps) == 3 and engine.done()
    # stepping a drained engine is a no-op
    tick = engine.tick
    assert engine.step() is False
    assert engine.tick == tick and engine.done()


def test_batcher_admission_invariants():
    """Pure scheduling: FCFS admission, chunk splitting, capacity limits."""
    b = Batcher(n_microbatches=2, mb_global=2, prefill_chunks=3, max_seq=32)
    rng = np.random.default_rng(0)
    mk = lambda i, p, g, t=0.0: Request(
        i, rng.integers(0, 100, (p,)).astype(np.int32), g, arrival=t)
    for i in range(6):
        b.enqueue(mk(i, 7 + i, 2, t=float(i < 3)))  # 3 arrive at t<=0.5...
    admitted = b.admit(now=1.0)
    assert len(admitted) == 4 == b.occupied()  # capacity-bound, FCFS
    assert [s.request.rid for s in admitted] == [0, 1, 2, 3]
    for s in admitted:
        chunks = s.chunks
        assert sum(c.shape[0] for c in chunks) == s.request.prompt_len
        assert len(chunks) == min(3, s.request.prompt_len)
        assert max(c.shape[0] for c in chunks) \
            - min(c.shape[0] for c in chunks) <= 1
    # a request that cannot fit the cache is rejected at enqueue
    with pytest.raises(ValueError):
        b.enqueue(mk(9, 31, 9))
    # releasing a slot frees capacity for the queue remainder
    admitted[0].release()
    again = b.admit(now=1.0)
    assert [s.request.rid for s in again] == [4]


# ---------------------------------------------------------------------------
# Multi-architecture co-serving
# ---------------------------------------------------------------------------


def _mk(rid, plen, gen, arrival=0.0, arch=0, deadline=None, seed=None):
    rng = np.random.default_rng(rid if seed is None else seed)
    return Request(rid, rng.integers(0, 100, (plen,)).astype(np.int32), gen,
                   arrival=arrival, arch=arch, deadline=deadline)


def test_multiarch_routing_never_crosses_arches():
    """Pure scheduling: arch a's requests land only in trial rows k == a,
    and an out-of-range arch id is rejected at enqueue."""
    b = Batcher(n_microbatches=2, mb_global=2, prefill_chunks=1, max_seq=32,
                n_trials=2)
    for i in range(6):
        b.enqueue(_mk(i, 8, 2, arch=i % 2))
    admitted = b.admit(now=1.0)
    assert len(admitted) == 6
    for s in admitted:
        assert s.k == s.request.arch
    with pytest.raises(ValueError):
        b.enqueue(_mk(9, 8, 2, arch=2))


def test_multiarch_backpressure_does_not_starve_other_arches():
    """Paged: pool exhaustion in one arch's partition defers only that arch;
    the other arch keeps admitting into its own partition."""
    # 2 trials x 1 shard: 8 blocks per (trial, shard) partition
    alloc = BlockAllocator(n_blocks=16, block_size=4, n_partitions=2)
    b = Batcher(n_microbatches=4, mb_global=1, prefill_chunks=1, max_seq=32,
                n_trials=2, allocator=alloc)
    # arch 0: three 16-token requests (4 blocks each) — the third overflows
    # the 8-block partition; arch 1: two small requests that must still admit
    for i in range(3):
        b.enqueue(_mk(i, 13, 4, arch=0))
    b.enqueue(_mk(3, 3, 2, arch=1))
    b.enqueue(_mk(4, 3, 2, arch=1))
    admitted = b.admit(now=1.0)
    by_arch = {k: sorted(s.request.rid for s in admitted if s.k == k)
               for k in (0, 1)}
    assert by_arch[0] == [0, 1]  # third deferred: per-arch backpressure
    assert by_arch[1] == [3, 4]  # ...but arch 1 was never starved
    assert b.committed_blocks(b.partition_of(0, 0)) == 8
    # releasing an arch-0 slot lets its deferred head move, FCFS
    next(s for s in admitted if s.request.rid == 0).release()
    assert [s.request.rid for s in b.admit(now=2.0)] == [2]


def test_policy_sjf_admits_shortest_prompt_first():
    b = Batcher(n_microbatches=1, mb_global=1, prefill_chunks=1, max_seq=32,
                policy="sjf")
    b.enqueue(_mk(0, 12, 2))
    b.enqueue(_mk(1, 4, 2))
    b.enqueue(_mk(2, 8, 2))
    assert [s.request.rid for s in b.admit(now=1.0)] == [1]
    # ...but never admits a request that has not arrived yet
    b.enqueue(_mk(3, 2, 2, arrival=99.0))
    next(s for s in b.slots if not s.free).release()
    assert [s.request.rid for s in b.admit(now=2.0)] == [2]


def test_policy_deadline_admits_earliest_deadline_first():
    b = Batcher(n_microbatches=1, mb_global=1, prefill_chunks=1, max_seq=32,
                policy="deadline")
    b.enqueue(_mk(0, 8, 2))  # no deadline: best-effort, sorts last
    b.enqueue(_mk(1, 8, 2, deadline=50.0))
    b.enqueue(_mk(2, 8, 2, deadline=10.0))
    order = []
    for _ in range(3):
        slots = b.admit(now=1.0)
        order.append(slots[0].request.rid)
        slots[0].release()
    assert order == [2, 1, 0]
    with pytest.raises(ValueError):
        Batcher(n_microbatches=1, mb_global=1, prefill_chunks=1, max_seq=32,
                policy="priority")


def test_multiarch_gang_matches_single_arch_and_oracle():
    """The acceptance bar: greedy tokens for every request in a mixed K-arch
    trace are bit-identical to serving its architecture alone through a
    single-arch engine, and to the single-device oracle."""
    cfg, opts, mesh, eng, params = build("chatglm3-6b", n_trials=2)
    reqs = staggered_trace(cfg.vocab_size, n_arches=2)
    gang = ServeEngine(cfg, eng, mesh, params, opts)
    comps = gang.run(_clone(reqs))
    assert [c.rid for c in comps] == [r.rid for r in reqs]
    # single-arch engines over each variant's own stream (same arrivals)
    solo = {}
    for k in range(2):
        eng_k = dataclasses.replace(eng, n_trials=1)
        params_k = jax.tree.map(lambda x: x[k:k + 1], params)
        engine = ServeEngine(cfg, eng_k, mesh, params_k, opts)
        mine = _clone([r for r in reqs if r.arch == k])
        for r in mine:  # the solo engine has one trial row: re-address
            r.arch = 0
        for c in engine.run(mine):
            solo[c.rid] = c
    for r, c in zip(reqs, comps):
        assert c.arch == r.arch
        assert c.tokens == solo[r.rid].tokens, \
            f"request {r.rid} (arch {r.arch}): gang != single-arch engine"
        assert c.tokens == oracle_tokens(cfg, opts, params, r, k=r.arch), \
            f"request {r.rid} (arch {r.arch}): gang diverged from the oracle"
    # the trial rows hold distinct weights, so the routing actually matters:
    # at least one request must decode differently under the other variant
    assert any(c.tokens != oracle_tokens(cfg, opts, params, r,
                                         k=1 - r.arch)
               for r, c in zip(reqs, comps)), \
        "variants emitted identical tokens — routing is untestable"


def test_multiarch_paged_matches_dense():
    """Paged multi-arch: per-trial pool slices + (trial, shard)-partitioned
    allocation must preserve bit-exactness against the dense gang."""
    cfg, opts, mesh, eng, params = build("chatglm3-6b", n_trials=2)
    paged = dataclasses.replace(eng, paged=True, block_size=4, n_blocks=24)
    reqs = staggered_trace(cfg.vocab_size, n_arches=2)
    dense_engine = ServeEngine(cfg, eng, mesh, params, opts)
    comp_dense = dense_engine.run(_clone(reqs))
    paged_engine = ServeEngine(cfg, paged, mesh, params, opts)
    comp_paged = paged_engine.run(_clone(reqs))
    for a, b in zip(comp_dense, comp_paged):
        assert a.tokens == b.tokens, \
            f"request {a.rid} (arch {a.arch}): paged != dense"
    assert paged_engine.allocator.n_partitions == 2  # one per trial
    assert paged_engine.allocator.all_free()


@pytest.mark.slow
def test_multiarch_paged_sharded_pool_matches_dense():
    """K=2 trials x data_size=2: four (trial, shard) pool partitions, each
    trial's pool leaf sliced over the data axis — exactness must survive the
    doubly-partitioned scatter/gather."""
    cfg, opts, mesh, eng, params = build("chatglm3-6b", n_stages=2,
                                         data_size=2, microbatch=1,
                                         n_trials=2)
    paged = dataclasses.replace(eng, paged=True, block_size=4, n_blocks=24)
    reqs = staggered_trace(cfg.vocab_size, seed=3, n_arches=2)
    dense_engine = ServeEngine(cfg, eng, mesh, params, opts)
    comp_dense = dense_engine.run(_clone(reqs))
    paged_engine = ServeEngine(cfg, paged, mesh, params, opts)
    comp_paged = paged_engine.run(_clone(reqs))
    for a, b in zip(comp_dense, comp_paged):
        assert a.tokens == b.tokens, \
            f"request {a.rid} (arch {a.arch}): paged != dense"
    assert paged_engine.allocator.n_partitions == 4
    assert paged_engine.batcher.n_shards == 2
    assert paged_engine.allocator.all_free()


# ---------------------------------------------------------------------------
# Sliding-window serving
# ---------------------------------------------------------------------------


def test_windowed_serving_matches_windowed_oracle():
    """eng.window > 0 through the continuous engine: greedy tokens must match
    the single-device oracle running the same sliding-window attention."""
    window = 6
    cfg, opts, mesh, eng, params = build("chatglm3-6b", window=window)
    reqs = staggered_trace(cfg.vocab_size)  # prompts up to 12 > window
    engine = ServeEngine(cfg, eng, mesh, params, opts)
    comps = engine.run(_clone(reqs))
    for r, c in zip(reqs, comps):
        assert c.tokens == oracle_tokens(cfg, opts, params, r,
                                         window=window), \
            f"request {r.rid}: windowed engine diverged from the oracle"
    # the window must actually bite: some request sees different tokens
    # than unwindowed greedy decoding
    full = [oracle_tokens(cfg, opts, params, r) for r in reqs]
    assert any(c.tokens != f for c, f in zip(comps, full)), \
        "window never masked anything — lengths too short for the test"


def test_windowed_paged_matches_windowed_oracle():
    """window + paged: the decode mask is applied through the gathered
    logical view of the block pool, so parity must hold there too."""
    window = 6
    cfg, opts, mesh, eng, params = build("chatglm3-6b", window=window)
    paged = dataclasses.replace(eng, paged=True, block_size=4, n_blocks=24)
    reqs = staggered_trace(cfg.vocab_size)
    engine = ServeEngine(cfg, paged, mesh, params, opts)
    comps = engine.run(_clone(reqs))
    for r, c in zip(reqs, comps):
        assert c.tokens == oracle_tokens(cfg, opts, params, r,
                                         window=window), \
            f"request {r.rid}: windowed paged engine diverged from the oracle"
    assert engine.allocator.all_free()


def test_windowed_serving_rejects_recurrent_families():
    cfg, opts, mesh, eng, params = build("falcon-mamba-7b", window=6)
    with pytest.raises(ValueError):
        ServeEngine(cfg, eng, mesh, params, opts)


# ---------------------------------------------------------------------------
# Fused mixed-tick admission (one pipeline call per round)
# ---------------------------------------------------------------------------


def _run_pair(cfg, eng, mesh, params, opts, reqs, **kw):
    """Run the same trace through the split and the fused schedule."""
    split = ServeEngine(cfg, eng, mesh, params, opts, **kw)
    comp_split = split.run(_clone(reqs), max_ticks=2000)
    fused = ServeEngine(cfg, eng, mesh, params, opts, fused=True, **kw)
    comp_fused = fused.run(_clone(reqs), max_ticks=2000)
    return split, comp_split, fused, comp_fused


def _assert_fused_parity(comp_split, comp_fused):
    """The fused schedule is a pure call-count optimization: every request's
    greedy tokens AND tick latencies must be bit-identical to split."""
    assert [c.rid for c in comp_fused] == [c.rid for c in comp_split]
    for a, b in zip(comp_split, comp_fused):
        assert b.tokens == a.tokens, f"request {a.rid}: fused != split"
        assert b.ttft_ticks == a.ttft_ticks, \
            f"request {a.rid}: fused shifted TTFT"
        assert b.finished_tick == a.finished_tick, \
            f"request {a.rid}: fused shifted completion"


def test_fused_matches_split_dense_and_oracle():
    """Dense strips: the mixed-tick call (ragged qlens, per-row sample
    gating) must reproduce the split schedule exactly in strictly fewer
    pipeline calls."""
    cfg, opts, mesh, eng, params = build("chatglm3-6b")
    reqs = staggered_trace(cfg.vocab_size)
    split, comp_split, fused, comp_fused = _run_pair(
        cfg, eng, mesh, params, opts, reqs)
    _assert_fused_parity(comp_split, comp_fused)
    for r, c in zip(reqs, comp_fused):
        assert c.tokens == oracle_tokens(cfg, opts, params, r), \
            f"request {r.rid}: fused diverged from the single-device oracle"
    assert fused.stats.calls < split.stats.calls, \
        (fused.stats.summary(), split.stats.summary())
    assert fused.stats.mixed_calls > 0
    assert 0.0 < fused.stats.mixed_fill_ratio <= 1.0
    # both engines decode the same slots each round, so the occupancy
    # metric must not degrade under fusion
    assert fused.stats.decode_occupancy >= split.stats.decode_occupancy


def test_fused_matches_split_paged():
    """Paged pool + block tables under the mixed call (per-row q-lengths in
    the scatter and the attention): parity and no block leaks."""
    cfg, opts, mesh, eng, params = build("chatglm3-6b")
    paged = dataclasses.replace(eng, paged=True, block_size=4, n_blocks=24)
    reqs = staggered_trace(cfg.vocab_size)
    split, comp_split, fused, comp_fused = _run_pair(
        cfg, paged, mesh, params, opts, reqs)
    _assert_fused_parity(comp_split, comp_fused)
    assert fused.stats.calls < split.stats.calls
    assert fused.allocator.all_free()


def test_fused_matches_split_prefix_cache():
    """Prefix-cache hits start chunked prefill at the hit boundary, so the
    mixed wave carries rows at staggered depths — parity must survive the
    CoW forks and the shortened waves, with the cache actually hitting."""
    cfg, opts, mesh, eng, params = build("chatglm3-6b")
    paged = dataclasses.replace(eng, paged=True, block_size=4, n_blocks=24)
    rng = np.random.default_rng(4)
    base_prompt = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
    reqs = [Request(i, np.concatenate(
                [base_prompt,
                 rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)]),
                    3 + i % 3, arrival=2.0 * i) for i in range(6)]
    split, comp_split, fused, comp_fused = _run_pair(
        cfg, paged, mesh, params, opts, reqs, prefix_cache=True)
    _assert_fused_parity(comp_split, comp_fused)
    assert fused.stats.prefix_hits > 0, "cache never hit — vacuous test"
    assert fused.stats.prefix_hits == split.stats.prefix_hits
    # arrivals 2.0 apart admit one request at a time, so split rounds are
    # already a single prefill group + decode — fusion can only tie here
    # (the admission-heavy traces above assert the strict win)
    assert fused.stats.calls <= split.stats.calls


def test_fused_matches_split_under_retraction():
    """Overcommit 1.5 on a 6-block pool: mid-prefill retraction requeues the
    victim and replays it — every request's greedy tokens must stay
    bit-identical to split. Tick latencies are NOT asserted here: the fused
    round is atomic, so a row retracted during wave preparation never ran
    this round's chunk, whereas split retracts it *after* its prefill call
    — preemption timing legitimately interleaves differently (the
    preemption-free tests above pin exact latency parity)."""
    cfg, opts, mesh, eng, params = build("chatglm3-6b")
    paged = dataclasses.replace(eng, paged=True, block_size=4, n_blocks=6)
    rng = np.random.default_rng(7)
    shapes = [(12, 5), (11, 6), (9, 4), (12, 6), (10, 5), (11, 4)]
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    (p,)).astype(np.int32), g, arrival=0.0)
            for i, (p, g) in enumerate(shapes)]
    split, comp_split, fused, comp_fused = _run_pair(
        cfg, paged, mesh, params, opts, reqs, overcommit=1.5)
    assert [c.rid for c in comp_fused] == [c.rid for c in comp_split]
    for a, b in zip(comp_split, comp_fused):
        assert b.tokens == a.tokens, \
            f"request {a.rid}: fused diverged under retraction"
    assert split.stats.retractions > 0 and fused.stats.retractions > 0, \
        "pool never pressured — the retraction path went untested"
    assert fused.allocator.all_free()
    assert fused.transfer.pending() == 0


def test_fused_rejects_recurrent_families():
    """Ragged mixed waves pad rows to the wave max; a recurrent state would
    advance through the padding, so fusion is attention-family only."""
    cfg, opts, mesh, eng, params = build("falcon-mamba-7b")
    with pytest.raises(ValueError, match="attention"):
        ServeEngine(cfg, eng, mesh, params, opts, fused=True)


@pytest.mark.slow
def test_fused_multiarch_sharded_matches_split():
    """K=2 trials x data_size=2: the qlens grid is sharded over the data
    axis like every other batch operand — parity must survive the
    doubly-partitioned mixed call."""
    cfg, opts, mesh, eng, params = build("chatglm3-6b", n_stages=2,
                                         data_size=2, microbatch=1,
                                         n_trials=2)
    paged = dataclasses.replace(eng, paged=True, block_size=4, n_blocks=24)
    reqs = staggered_trace(cfg.vocab_size, seed=3, n_arches=2)
    split, comp_split, fused, comp_fused = _run_pair(
        cfg, paged, mesh, params, opts, reqs)
    _assert_fused_parity(comp_split, comp_fused)
    assert fused.stats.calls < split.stats.calls
    assert fused.allocator.all_free()


# ---------------------------------------------------------------------------
# Latency metrics
# ---------------------------------------------------------------------------


def test_latency_metrics_recorded():
    cfg, opts, mesh, eng, params = build("chatglm3-6b")
    reqs = staggered_trace(cfg.vocab_size)
    engine = ServeEngine(cfg, eng, mesh, params, opts)
    comps = engine.run(_clone(reqs))
    for c in comps:
        assert c.first_token_tick >= c.admitted_tick >= 0
        assert c.ttft_ticks >= 0
        assert c.finished_tick >= c.first_token_tick
        if len(c.tokens) > 1:
            # can dip below 1 tick/token (even to 0): the round the last
            # prefill chunk lands also runs that slot's first decode
            assert c.tpot_ticks >= 0
    s = engine.stats.summary()
    for key in ("ttft_p50", "ttft_p95", "tpot_p50", "tpot_p95"):
        assert key in s and s[key] >= 0
    assert len(engine.stats.ttft_samples) == len(reqs)
