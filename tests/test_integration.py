"""Driver end-to-end tests, each run in a subprocess (the launchers own
their process: argv parsing, env setup, stdout reporting).

The pipeline/serve exactness checks that used to hide behind subprocess
wrappers here are now ordinary pytest modules under ``tests/integration/``
(collected in-process — tests/conftest.py provides the fake devices).
"""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ,
       "PYTHONPATH": os.path.join(ROOT, "src"),
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}


def _run(args, timeout=540):
    proc = subprocess.run([sys.executable] + args, env=ENV, cwd=ROOT,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout[-3000:]}\nSTDERR:\n{proc.stderr[-3000:]}"
    return proc.stdout


def test_train_driver_end_to_end(tmp_path):
    out = _run(["-m", "repro.launch.train", "--arch", "chatglm3-6b",
                "--smoke", "--trials", "2", "--steps", "4",
                "--n-data", "2", "--n-model", "4",
                "--n-microbatches", "2", "--seq-len", "16",
                "--ckpt-dir", str(tmp_path)])
    assert "best_trial" in out


def test_serve_driver_continuous_end_to_end():
    out = _run(["-m", "repro.launch.serve", "--arch", "chatglm3-6b",
                "--smoke", "--n-data", "2", "--n-model", "4",
                "--slots", "3", "--prompt-len", "8", "--gen-len", "4",
                "--n-requests", "8", "--rate", "2.0"])
    assert "continuous:" in out and "slot occupancy" in out


def test_serve_driver_trace_replay(tmp_path):
    """--trace replays a recorded JSONL request stream."""
    trace = tmp_path / "stream.jsonl"
    gen = _run(["-c", (
        "from repro.serve import poisson_trace, save_trace; "
        "save_trace(%r, poisson_trace(5, 1.0, 128, prompt_lens=(4, 8), "
        "gen_lens=(2, 4), seed=3))") % str(trace)])
    assert trace.exists(), gen
    out = _run(["-m", "repro.launch.serve", "--arch", "chatglm3-6b",
                "--smoke", "--n-data", "1", "--n-model", "2",
                "--slots", "2", "--prompt-len", "8", "--gen-len", "4",
                "--trace", str(trace)])
    assert "5 requests" in out and "slot occupancy" in out
