"""Multi-device integration tests, each run in a subprocess with fake host
devices (jax locks the device count at first init, so the main pytest
process stays single-device — per the dry-run isolation rule)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ,
       "PYTHONPATH": os.path.join(ROOT, "src"),
       "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}


def _run(args, timeout=540):
    proc = subprocess.run([sys.executable] + args, env=ENV, cwd=ROOT,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, \
        f"STDOUT:\n{proc.stdout[-3000:]}\nSTDERR:\n{proc.stderr[-3000:]}"
    return proc.stdout


@pytest.mark.parametrize("arch", ["chatglm3-6b", "granite-moe-3b-a800m",
                                  "falcon-mamba-7b", "zamba2-7b"])
def test_pipeline_exactness(arch):
    out = _run(["tests/integration/pipeline_exactness.py", arch])
    assert "EXACTNESS OK" in out


def test_pipeline_exactness_fsdp():
    out = _run(["tests/integration/pipeline_exactness.py", "chatglm3-6b",
                "fsdp"])
    assert "EXACTNESS OK" in out


@pytest.mark.parametrize("arch", ["chatglm3-6b", "falcon-mamba-7b"])
def test_serve_pipeline(arch):
    out = _run(["tests/integration/serve_pipeline_check.py", arch])
    assert "SERVE PIPELINE OK" in out


def test_train_driver_end_to_end(tmp_path):
    out = _run(["-m", "repro.launch.train", "--arch", "chatglm3-6b",
                "--smoke", "--trials", "2", "--steps", "4",
                "--n-data", "2", "--n-model", "4",
                "--n-microbatches", "2", "--seq-len", "16",
                "--ckpt-dir", str(tmp_path)])
    assert "best_trial" in out


def test_serve_driver_end_to_end():
    out = _run(["-m", "repro.launch.serve", "--arch", "chatglm3-6b",
                "--smoke", "--n-data", "2", "--n-model", "4",
                "--batch", "3", "--prompt-len", "8", "--gen-len", "4"])
    assert "generated" in out


def test_chunked_prefill_exactness():
    """Chunked prefill (sequence chunks as Hydra slots) must match plain
    prefill exactly — tokens and caches — across attention/SSM/hybrid."""
    out = _run(["tests/integration/chunked_prefill_check.py"])
    assert "CHUNKED PREFILL OK" in out
