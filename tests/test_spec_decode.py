"""Gang-speculative decoding: drafter trial rows propose, target rows verify
in one ragged append call. The contract is exact greedy equivalence — tokens
bit-identical to the target-only engine AND the single-device oracle — at
strictly fewer target-row pipeline ticks per output token; drafter quality
only moves the acceptance rate. Rejected proposals roll the paged block
tables back (BlockTable.truncate), which must leave allocator state
bit-identical to never having speculated — including under overcommit
retraction.

(Multi-device setup comes from tests/conftest.py — pytest-only module.)"""
import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.serve import Request, ServeEngine  # noqa: E402

from test_serve_engine import build, oracle_tokens  # noqa: E402

GAMMA = 3


def spec_trace(vocab, seed=3, n=5):
    """Longer generations than the base serve traces: speculation amortises
    per-tick cost over accepted runs, so the win shows on gen-heavy rows."""
    rng = np.random.default_rng(seed)
    shapes = [(8, 8), (11, 6), (7, 7), (10, 5), (8, 8), (11, 6)][:n]
    return [Request(i, rng.integers(0, vocab, (p,)).astype(np.int32), g,
                    arrival=0.5 * i) for i, (p, g) in enumerate(shapes)]


def spec_build(paged=False, **eng_over):
    """Two-trial gang (row 0 target, row 1 drafter) + the equal-target-
    capacity baseline: the same grid minus the drafter row."""
    cfg, opts, mesh, eng, params = build("chatglm3-6b", n_trials=2)
    if paged:
        eng = dataclasses.replace(eng, paged=True, block_size=4, n_blocks=40)
    eng = dataclasses.replace(eng, **eng_over)
    params_tgt = jax.tree.map(lambda x: x[:1], params)
    # mirroring row 0's weights onto the drafter row pins acceptance at 1.0
    params_perf = jax.tree.map(lambda x: jnp.concatenate([x[:1], x[:1]], 0),
                               params)
    eng_tgt = dataclasses.replace(eng, n_trials=1)
    return cfg, opts, mesh, eng, eng_tgt, params, params_perf, params_tgt


def run(cfg, eng, mesh, params, opts, reqs, **kw):
    e = ServeEngine(cfg, eng, mesh, params, opts, **kw)
    comps = e.run([r.clone() for r in reqs])
    return e, {c.rid: c.tokens for c in comps}


def target_ticks_per_token(e, spec=False):
    s = e.stats
    tgt = (s.prefill_calls + e.spec_stats.verify_calls) if spec else s.calls
    return tgt / max(s.tokens_generated, 1)


def test_perfect_drafter_paged_parity_and_fewer_target_ticks():
    cfg, opts, mesh, eng, eng_tgt, _, params_perf, params_tgt = \
        spec_build(paged=True)
    reqs = spec_trace(cfg.vocab_size)
    e_base, toks_base = run(cfg, eng_tgt, mesh, params_tgt, opts, reqs)
    e_spec, toks_spec = run(cfg, eng, mesh, params_perf, opts, reqs,
                            spec_gamma=GAMMA)
    for r in reqs:
        assert toks_spec[r.rid] == toks_base[r.rid], \
            f"request {r.rid}: speculative != target-only"
        assert toks_spec[r.rid] == oracle_tokens(cfg, opts, params_tgt, r), \
            f"request {r.rid}: speculative != single-device oracle"
    assert e_spec.spec_stats.acceptance_rate == 1.0
    # the perf contract: strictly fewer target-row ticks per output token
    assert target_ticks_per_token(e_spec, spec=True) < \
        target_ticks_per_token(e_base)
    assert e_spec.allocator.all_free() and e_base.allocator.all_free()


def test_mixed_drafter_parity_with_rollback():
    """An untrained drafter (row 1's own init) is rejected nearly every
    round: tokens must still be bit-identical and every speculatively-grown
    block must be rolled back into a clean pool."""
    cfg, opts, mesh, eng, eng_tgt, params, _, params_tgt = \
        spec_build(paged=True)
    reqs = spec_trace(cfg.vocab_size, seed=4)
    _, toks_base = run(cfg, eng_tgt, mesh, params_tgt, opts, reqs)
    e_spec, toks_spec = run(cfg, eng, mesh, params, opts, reqs,
                            spec_gamma=GAMMA)
    for r in reqs:
        assert toks_spec[r.rid] == toks_base[r.rid], \
            f"request {r.rid}: rejected speculation changed tokens"
    assert e_spec.spec_stats.rollback_blocks > 0, \
        "mixed drafter never exercised block rollback"
    assert e_spec.spec_stats.acceptance_rate < 1.0
    assert e_spec.allocator.all_free()
    assert e_spec.store.rollbacks == e_spec.spec_stats.rollback_blocks


def test_dense_spec_parity():
    """Speculation is cache-layout agnostic: the dense strip path rewinds by
    position (s.pos) alone — no block bookkeeping to roll back."""
    cfg, opts, mesh, eng, eng_tgt, _, params_perf, params_tgt = spec_build()
    reqs = spec_trace(cfg.vocab_size, n=4)
    _, toks_base = run(cfg, eng_tgt, mesh, params_tgt, opts, reqs)
    e_spec, toks_spec = run(cfg, eng, mesh, params_perf, opts, reqs,
                            spec_gamma=GAMMA)
    for r in reqs:
        assert toks_spec[r.rid] == toks_base[r.rid]
    assert e_spec.spec_stats.acceptance_rate == 1.0


def test_overcommit_retraction_parity():
    """Rollback composes with preemption: a pool sized to force retraction
    mid-stream must still produce bit-identical tokens, with both the victim
    pair's cells and blocks recovered."""
    cfg, opts, mesh, eng, eng_tgt, _, params_perf, params_tgt = \
        spec_build(paged=True, n_blocks=7)
    reqs = spec_trace(cfg.vocab_size, seed=5, n=6)
    for r in reqs:
        r.arrival = 0.0  # all at once: admission overcommits immediately
    _, toks_base = run(cfg, eng_tgt, mesh, params_tgt, opts, reqs,
                       overcommit=1.5, host_blocks=16)
    e_spec, toks_spec = run(cfg, eng, mesh, params_perf, opts, reqs,
                            spec_gamma=GAMMA, overcommit=1.5, host_blocks=16)
    assert e_spec.stats.retractions > 0, \
        "pool never forced a retraction — shrink n_blocks"
    for r in reqs:
        assert toks_spec[r.rid] == toks_base[r.rid], \
            f"request {r.rid}: retraction broke speculative parity"
    assert e_spec.allocator.all_free()


def test_enqueue_to_draft_row_raises():
    cfg, opts, mesh, eng, _, _, params_perf, _ = spec_build()
    e = ServeEngine(cfg, eng, mesh, params_perf, opts, spec_gamma=GAMMA)
    rng = np.random.default_rng(0)
    bad = Request(0, rng.integers(0, cfg.vocab_size, (6,)).astype(np.int32),
                  2, arch=1)  # row 1 is the drafter mirror, not a queue
    with pytest.raises(ValueError):
        e.batcher.enqueue(bad)


def test_spec_config_validation():
    cfg, opts, mesh, eng, _, _, params_perf, _ = spec_build()
    with pytest.raises(ValueError):  # fused and spec both own the round
        ServeEngine(cfg, eng, mesh, params_perf, opts, spec_gamma=GAMMA,
                    fused=True)
    with pytest.raises(ValueError):  # target and drafter rows must differ
        ServeEngine(cfg, eng, mesh, params_perf, opts, spec_gamma=GAMMA,
                    spec_pairs={0: 0})
    odd = dataclasses.replace(eng, n_trials=3)
    with pytest.raises(ValueError):  # no default pairing on odd n_trials
        ServeEngine(cfg, odd, mesh, params_perf, opts, spec_gamma=GAMMA)
