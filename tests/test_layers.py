"""Layer numerics: chunked attention vs direct oracle, Mamba1/Mamba2 chunked
forms vs step-by-step recurrence, RoPE variants, MoE dispatch conservation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, SSMConfig
from repro.models import layers as L

RNG = np.random.default_rng(0)


def _rand(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


@pytest.mark.parametrize("sq,sk,causal,window,off,gqa", [
    (64, 64, True, 0, 0, 1),
    (33, 33, True, 0, 0, 2),
    (7, 39, True, 0, 32, 4),
    (16, 16, False, 0, 0, 1),
    (64, 64, True, 24, 0, 2),
])
def test_chunked_attention_matches_reference(sq, sk, causal, window, off, gqa):
    b, hkv, hd = 2, 2, 16
    q = _rand(b, sq, hkv * gqa, hd)
    k = _rand(b, sk, hkv, hd)
    v = _rand(b, sk, hkv, hd)
    kv_len = jnp.array([sk, max(sk - 5, 1)])
    ref = L.attention_reference(q, k, v, causal=causal, window=window,
                                kv_offset=off, kv_len=kv_len)
    out = L.chunked_attention(q, k, v, causal=causal, window=window,
                              kv_offset=off, kv_len=kv_len,
                              q_chunk=16, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               atol=3e-5, rtol=1e-4)


def test_attention_grads_flow_through_chunks():
    q = _rand(1, 40, 4, 16)
    k = _rand(1, 40, 2, 16)
    v = _rand(1, 40, 2, 16)

    def f(q, k, v):
        return L.chunked_attention(q, k, v, causal=True, q_chunk=16,
                                   kv_chunk=8).sum()

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for x in g:
        assert jnp.all(jnp.isfinite(x))
        assert float(jnp.abs(x).max()) > 0


def _mamba1_cfg():
    return ArchConfig(
        name="m1", family="ssm", n_layers=2, d_model=32, n_heads=0,
        n_kv_heads=0, d_ff=0, vocab_size=64, rope="none",
        ssm=SSMConfig(kind="mamba1", d_state=8, d_conv=4, expand=2,
                      dt_rank=4, chunk_size=8))


def _mamba1_params(di=64, n=8, r=4, d=32):
    return {
        "in_proj": _rand(d, 2 * di, scale=0.1),
        "conv_w": _rand(di, 4, scale=0.3),
        "conv_b": jnp.zeros((di,)),
        "x_proj": _rand(di, r + 2 * n, scale=0.1),
        "dt_proj": _rand(r, di, scale=0.3),
        "dt_bias": jnp.zeros((di,)),
        "A_log": _rand(di, n, scale=0.1),
        "D": jnp.ones((di,)),
        "out_proj": _rand(di, d, scale=0.1),
    }


def test_mamba1_chunked_equals_step_decode():
    cfg = _mamba1_cfg()
    p = _mamba1_params()
    x = _rand(2, 21, 32)
    y, hs, cs = L.mamba1_mix(p, x, cfg)
    h, c = None, jnp.zeros((2, 3, 64))
    ys = []
    for t in range(21):
        yt, h, c = L.mamba1_mix(p, x[:, t:t + 1], cfg, h, c)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(jnp.concatenate(ys, 1)), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(h), atol=1e-4)
    np.testing.assert_allclose(np.asarray(cs), np.asarray(c), atol=1e-5)


def test_mamba2_ssd_equals_step_decode():
    cfg = ArchConfig(
        name="m2", family="hybrid", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab_size=64, rope="1d", head_dim=8,
        ssm=SSMConfig(kind="mamba2", d_state=8, d_conv=4, expand=2,
                      head_dim=16, n_groups=2, chunk_size=8))
    di, n, g, nh = 64, 8, 2, 4
    conv_dim = di + 2 * g * n
    p = {
        "in_proj": _rand(32, 2 * di + 2 * g * n + nh, scale=0.1),
        "conv_w": _rand(conv_dim, 4, scale=0.3),
        "conv_b": jnp.zeros((conv_dim,)),
        "dt_bias": jnp.zeros((nh,)),
        "A_log": _rand(nh, scale=0.1),
        "D": jnp.ones((nh,)),
        "norm_w": jnp.ones((di,)),
        "out_proj": _rand(di, 32, scale=0.1),
    }
    x = _rand(2, 21, 32)
    y, hs, _ = L.mamba2_mix(p, x, cfg)
    h, c = None, jnp.zeros((2, 3, conv_dim))
    ys = []
    for t in range(21):
        yt, h, c = L.mamba2_mix(p, x[:, t:t + 1], cfg, h, c)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(jnp.concatenate(ys, 1)), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(h), atol=1e-4)


@pytest.mark.parametrize("rope", ["1d", "2d", "mrope"])
def test_rope_orthogonality(rope):
    """Rotary application preserves vector norms (rotation property)."""
    cfg = ArchConfig(name="r", family="dense", n_layers=1, d_model=64,
                     n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=32,
                     rope=rope, head_dim=16)
    x = _rand(2, 8, 4, 16)
    if rope == "mrope":
        pos = jnp.broadcast_to(jnp.arange(8), (3, 2, 8))
    else:
        pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    y = L.apply_rope(x, pos, cfg)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(x, axis=-1)),
                               np.asarray(jnp.linalg.norm(y, axis=-1)),
                               rtol=1e-5)


def test_rope_relative_position_property():
    """1d RoPE: <q_m, k_n> depends only on (m - n)."""
    cfg = ArchConfig(name="r", family="dense", n_layers=1, d_model=64,
                     n_heads=1, n_kv_heads=1, d_ff=64, vocab_size=32,
                     rope="1d", head_dim=16)
    q = _rand(1, 1, 1, 16)
    k = _rand(1, 1, 1, 16)

    def dot_at(m, n):
        qm = L.apply_rope(q, jnp.array([[m]]), cfg)
        kn = L.apply_rope(k, jnp.array([[n]]), cfg)
        return float(jnp.sum(qm * kn))

    assert abs(dot_at(5, 3) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(7, 0) - dot_at(17, 10)) < 1e-4


def test_moe_dropless_equals_dense_mixture():
    """With capacity >= all tokens, scatter-dispatch MoE must equal the dense
    gate-weighted mixture of expert outputs."""
    d, e, f, t = 16, 4, 8, 24
    p = {"router": _rand(d, e, scale=0.5),
         "w_gate": _rand(e, d, f, scale=0.3),
         "w_up": _rand(e, d, f, scale=0.3),
         "w_down": _rand(e, f, d, scale=0.3)}
    x = _rand(2, 12, d)
    out, aux = L.moe_apply(p, x, n_experts=e, top_k=2, capacity_factor=64.0,
                           act="swiglu")
    # dense oracle
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)
    dense = jnp.zeros_like(x)
    for ei in range(e):
        g_ = jnp.einsum("bsd,df->bsf", x, p["w_gate"][ei])
        u_ = jnp.einsum("bsd,df->bsf", x, p["w_up"][ei])
        ye = jnp.einsum("bsf,fd->bsd", jax.nn.silu(g_) * u_, p["w_down"][ei])
        w = jnp.where(gi == ei, gv, 0.0).sum(-1)
        dense += ye * w[..., None]
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense), atol=2e-5)
    assert float(aux) > 0


def test_moe_expert_chunking_matches_unchunked():
    d, e, f = 16, 8, 8
    p = {"router": _rand(d, e, scale=0.5),
         "w_gate": _rand(e, d, f, scale=0.3),
         "w_up": _rand(e, d, f, scale=0.3),
         "w_down": _rand(e, f, d, scale=0.3)}
    x = _rand(2, 12, d)
    o1, _ = L.moe_apply(p, x, n_experts=e, top_k=2, capacity_factor=2.0)
    o2, _ = L.moe_apply(p, x, n_experts=e, top_k=2, capacity_factor=2.0,
                        expert_chunk=2)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
