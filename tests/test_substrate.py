"""Substrate tests: optimizer, data pipeline, checkpointing, fault-tolerant
loop, HLO analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo as hlo_lib
from repro.checkpoint import ckpt
from repro.core.pipeline import EngineConfig
from repro.data.pipeline import HostShard, SyntheticTokenSource, TrainBatches
from repro.optim.adamw import AdamW, SGD, warmup_cosine_schedule
from repro.runtime.fault_tolerance import LoopConfig, run_with_restarts


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------


def test_adamw_per_trial_lrs_differ():
    params = {"w": jnp.ones((2, 4))}  # K=2 trials
    grads = {"w": jnp.ones((2, 4))}
    opt = AdamW()
    state = opt.init(params)
    hp = {"lr": jnp.array([1e-1, 1e-3])}
    new, _ = opt.update(params, grads, state, hp, jnp.int32(0))
    d0 = float(jnp.abs(params["w"][0] - new["w"][0]).max())
    d1 = float(jnp.abs(params["w"][1] - new["w"][1]).max())
    assert d0 > d1 * 50  # lr ratio reflected (Adam normalizes magnitude)


def test_adamw_first_step_is_lr_sized():
    params = {"w": jnp.zeros((1, 3))}
    grads = {"w": jnp.full((1, 3), 0.5)}
    opt = AdamW()
    st = opt.init(params)
    new, st = opt.update(params, grads, st, {"lr": jnp.array([0.01])},
                         jnp.int32(0))
    # bias-corrected adam first step = -lr * g/|g| = -lr
    np.testing.assert_allclose(np.asarray(new["w"]), -0.01, rtol=1e-4)


def test_adamw_clip_scales_update():
    params = {"w": jnp.zeros((1, 4))}
    g_small = {"w": jnp.full((1, 4), 0.1)}
    g_big = {"w": jnp.full((1, 4), 100.0)}
    opt = AdamW(grad_clip=1.0)
    hp = {"lr": jnp.array([0.01])}
    st = opt.init(params)
    n1, _ = opt.update(params, g_small, st, hp, jnp.int32(0),
                       grad_norm=jnp.array([0.2]))
    st = opt.init(params)
    n2, _ = opt.update(params, g_big, st, hp, jnp.int32(0),
                       grad_norm=jnp.array([200.0]))
    # both end up at -lr after adam normalization; clip must not NaN/blow up
    assert jnp.all(jnp.isfinite(n1["w"])) and jnp.all(jnp.isfinite(n2["w"]))


def test_schedule_warmup_cosine():
    f = warmup_cosine_schedule(warmup=10, total=100)
    assert float(f(jnp.int32(0))) == 0.0
    assert abs(float(f(jnp.int32(10))) - 1.0) < 1e-6
    assert float(f(jnp.int32(100))) < 0.11


def test_sgd_momentum():
    params = {"w": jnp.zeros((1, 2))}
    opt = SGD(momentum=0.9)
    st = opt.init(params)
    hp = {"lr": jnp.array([1.0])}
    g = {"w": jnp.ones((1, 2))}
    p1, st = opt.update(params, g, st, hp, jnp.int32(0))
    p2, st = opt.update(p1, g, st, hp, jnp.int32(1))
    np.testing.assert_allclose(np.asarray(p2["w"]), -1.0 - 1.9, rtol=1e-6)


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------


def test_data_determinism_and_shift():
    cfg = __import__("repro.configs", fromlist=["x"]).get_config(
        "chatglm3-6b").reduced()
    eng = EngineConfig(n_trials=2, n_microbatches=2, microbatch=2,
                       n_stages=2, data_size=2)
    d1 = TrainBatches(cfg, eng, seq_len=16, seed=7)
    d2 = TrainBatches(cfg, eng, seq_len=16, seed=7)
    b1, b2 = d1.batch_for_step(3), d2.batch_for_step(3)
    d1.close(), d2.close()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shift
    np.testing.assert_array_equal(b1["tokens"][..., 1:],
                                  b1["labels"][..., :-1])
    assert b1["tokens"].shape == (2, 2, 4, 16)  # (K, M, mb*data, seq)
    assert b1["tokens"].max() < cfg.vocab_size


def test_data_distinct_across_coordinates():
    src = SyntheticTokenSource(vocab_size=1000, seq_len=32, seed=0)
    a = src.sequence(0, 0, 0, 0)
    assert not np.array_equal(a, src.sequence(1, 0, 0, 0))
    assert not np.array_equal(a, src.sequence(0, 1, 0, 0))
    assert not np.array_equal(a, src.sequence(0, 0, 1, 0))
    np.testing.assert_array_equal(a, SyntheticTokenSource(
        1000, 32, 0).sequence(0, 0, 0, 0))


def test_host_sharding_partitions_rows():
    rows = [list(HostShard(i, 4).rows(26)) for i in range(4)]
    flat = [r for rs in rows for r in rs]
    assert sorted(flat) == list(range(26))


def test_prefetch_iterator():
    cfg = __import__("repro.configs", fromlist=["x"]).get_config(
        "chatglm3-6b").reduced()
    eng = EngineConfig(n_trials=1, n_microbatches=1, microbatch=2,
                       n_stages=1, data_size=1)
    data = TrainBatches(cfg, eng, seq_len=8, seed=0, prefetch=2)
    b0 = next(data)
    b1 = next(data)
    data.close()
    assert b0["tokens"].shape == b1["tokens"].shape
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# --------------------------------------------------------------------------
# checkpointing + fault tolerance
# --------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "count": jnp.int32(7)}
    ckpt.save(str(tmp_path), 42, tree, extra={"note": "x"})
    assert ckpt.latest_step(str(tmp_path)) == 42
    back = ckpt.restore(str(tmp_path), 42, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert back["b"]["c"].dtype == np.dtype("bfloat16") or \
        np.asarray(back["b"]["c"]).dtype.name == "bfloat16"
    assert ckpt.manifest(str(tmp_path), 42)["extra"]["note"] == "x"


def test_checkpoint_cleanup_keeps_latest(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree)
    ckpt.cleanup(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    assert ckpt.restore(str(tmp_path), 4, tree) is not None
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), 1, tree)


def test_async_checkpointer(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    saver.save(10, {"w": jnp.ones((8, 8))})
    saver.wait()
    assert ckpt.latest_step(str(tmp_path)) == 10


def test_restart_resumes_and_matches_uninterrupted(tmp_path):
    """Injected failure at step 7: the restarted run must produce the exact
    same final state as an uninterrupted run (determinism contract)."""

    def step_fn(state, step):
        return {"x": state["x"] + (step + 1)}, {"step": step}

    init = {"x": jnp.zeros(())}
    clean = run_with_restarts(step_fn, init,
                              LoopConfig(n_steps=10, checkpoint_every=2,
                                         ckpt_dir=str(tmp_path / "clean")))

    boom = {"armed": True}

    def injector(step):
        if step == 7 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("simulated chip failure")

    faulty = run_with_restarts(step_fn, init,
                               LoopConfig(n_steps=10, checkpoint_every=2,
                                          ckpt_dir=str(tmp_path / "faulty")),
                               failure_injector=injector)
    assert faulty.restarts == 1
    assert float(faulty.final_state["x"]) == float(clean.final_state["x"])


def test_restart_exhaustion_raises(tmp_path):
    def step_fn(state, step):
        raise RuntimeError("always fails")

    with pytest.raises(RuntimeError):
        run_with_restarts(step_fn, {"x": jnp.zeros(())},
                          LoopConfig(n_steps=3, checkpoint_every=1,
                                     ckpt_dir=str(tmp_path),
                                     max_restarts=2))


# --------------------------------------------------------------------------
# HLO analyzer (roofline input)
# --------------------------------------------------------------------------


def test_hlo_analyzer_counts_loops_and_collectives():
    import jax
    from jax import lax
    from jax.sharding import PartitionSpec as P
    from repro import compat
    if jax.device_count() < 2:
        pytest.skip("needs >=2 devices")
    mesh = compat.make_mesh((2,), ("x",))

    def inner(w, x):
        def body(c, _):
            y = jnp.dot(c, w)
            y = lax.psum(y, "x")
            return y, ()
        out, _ = lax.scan(body, x, None, length=5)
        return out

    f = compat.shard_map(inner, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
                         check_vma=False)
    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((16, 16), jnp.float32),
        jax.ShapeDtypeStruct((4, 16), jnp.float32))
    costs = hlo_lib.analyze(lowered.compile().as_text())
    assert costs.trip_counts == [5]
    np.testing.assert_allclose(costs.flops, 2 * 4 * 16 * 16 * 5, rtol=0.05)
    # ring all-reduce bytes: 2 * B * (n-1)/n per execution
    want = 5 * 2 * (4 * 16 * 4) * (2 - 1) / 2
    np.testing.assert_allclose(costs.collective_bytes, want, rtol=0.05)
