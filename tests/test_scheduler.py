"""Unit coverage for the shard-parallel task scheduler: gang-planning
invariants, failure re-planning conservation, serving capacity planning, and
simulator speedup monotonicity."""
import dataclasses

import pytest

from repro.configs import ASSIGNED_ARCHS
from repro.core import scheduler as sched
from repro.core import simulator as sim
from repro.core.pipeline import EngineConfig

SEQ = 128
BUDGET = sched.HBM_BYTES_PER_CHIP * sched.HBM_BUDGET_FRACTION


def base_eng(**kw):
    kw.setdefault("n_trials", 1)
    kw.setdefault("n_microbatches", 1)
    kw.setdefault("microbatch", 2)
    kw.setdefault("n_stages", 4)
    kw.setdefault("data_size", 2)
    return EngineConfig(**kw)


def trial_population():
    """Mixed-architecture population with unique tags."""
    trials = []
    for arch, n in (("chatglm3-6b", 5), ("falcon-mamba-7b", 3),
                    ("granite-moe-3b-a800m", 2)):
        for i in range(n):
            trials.append(sched.TrialSpec(arch=arch, lr=1e-3 * (i + 1),
                                          tag=f"{arch}/{i}"))
    return trials


def arch_configs():
    return {name: ASSIGNED_ARCHS[name].reduced()
            for name in ("chatglm3-6b", "falcon-mamba-7b",
                         "granite-moe-3b-a800m")}


# ---------------------------------------------------------------------------
# plan_gangs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("target", [0.05, 0.10, 0.25])
def test_plan_gangs_invariants(target):
    trials = trial_population()
    cfgs = arch_configs()
    eng = base_eng()
    gangs = sched.plan_gangs(trials, eng, cfgs, SEQ, target_bubble=target)

    # every trial lands in exactly one gang, arch-homogeneous
    placed = [t.tag for g in gangs for t in g.trials]
    assert sorted(placed) == sorted(t.tag for t in trials)
    for g in gangs:
        assert all(t.arch == g.arch for t in g.trials)
        k = len(g.trials)
        assert k == g.engine.n_trials
        # gang size bounded by the per-chip memory ceiling
        k_max = sched.max_concurrent_trials(cfgs[g.arch], eng, SEQ)
        assert 1 <= k <= k_max
        # bubble target met unless the memory budget forced M down
        s = eng.n_stages
        m = g.engine.n_microbatches
        import math
        m_needed = max(1, math.ceil((s - 1) * (1 - target) / (target * k)))
        assert g.bubble_fraction <= target or m < m_needed
        # whatever M was chosen must fit the budget (or be irreducible)
        mem = sched.per_chip_bytes(cfgs[g.arch], g.engine, SEQ,
                                   train=True).total * k
        assert mem <= BUDGET or m == 1


def test_plan_gangs_tightening_target_never_shrinks_slots():
    """A tighter bubble target can only demand more microbatches."""
    trials = trial_population()
    cfgs = arch_configs()
    eng = base_eng()
    loose = sched.plan_gangs(trials, eng, cfgs, SEQ, target_bubble=0.25)
    tight = sched.plan_gangs(trials, eng, cfgs, SEQ, target_bubble=0.05)
    m_loose = {g.arch: g.engine.n_microbatches for g in loose}
    for g in tight:
        assert g.engine.n_microbatches >= m_loose[g.arch]


# ---------------------------------------------------------------------------
# replan_after_failure
# ---------------------------------------------------------------------------


def test_replan_after_failure_conserves_trials():
    trials = trial_population()
    cfgs = arch_configs()
    eng = base_eng(data_size=4)
    gangs = sched.plan_gangs(trials, eng, cfgs, SEQ)
    replanned = sched.replan_after_failure(gangs, eng, cfgs, SEQ,
                                           lost_data_rows=2)
    before = sorted(t.tag for g in gangs for t in g.trials)
    after = sorted(t.tag for g in replanned for t in g.trials)
    assert before == after
    for g in replanned:
        assert g.engine.data_size == 2


def test_replan_after_total_loss_raises():
    trials = trial_population()
    cfgs = arch_configs()
    eng = base_eng(data_size=2)
    gangs = sched.plan_gangs(trials, eng, cfgs, SEQ)
    with pytest.raises(RuntimeError):
        sched.replan_after_failure(gangs, eng, cfgs, SEQ, lost_data_rows=2)


# ---------------------------------------------------------------------------
# plan_serve_capacity
# ---------------------------------------------------------------------------


def test_plan_serve_capacity_fits_budget_and_meets_bubble():
    cfg = ASSIGNED_ARCHS["chatglm3-6b"].reduced()
    eng = base_eng()
    planned = sched.plan_serve_capacity(cfg, eng, max_seq=256,
                                        target_bubble=0.25)
    assert planned.n_trials == 1
    mem = sched.per_chip_bytes(cfg, planned, 256, train=False).total
    assert mem <= BUDGET
    # tiny smoke config: memory is no constraint, bubble target binds
    assert planned.bubble_fraction <= 0.25
    # serving memory is cache-dominated: more slots than one lockstep batch
    assert planned.n_microbatches >= eng.n_microbatches


def test_plan_serve_capacity_paged_admits_more_at_equal_budget():
    """The tentpole claim at the planner level: with the same HBM budget the
    paged plan backs strictly more slot cells than dense worst-case strips
    whenever expected length < max_seq, and its pool actually fits."""
    cfg = ASSIGNED_ARCHS["chatglm3-6b"].reduced()
    eng = base_eng()
    max_seq = 256
    est = sched.per_chip_bytes(cfg, dataclasses.replace(
        eng, n_trials=1, n_microbatches=1), max_seq, train=False)
    strip = eng.microbatch * max_seq * sched.kv_token_bytes_per_chip(cfg, eng)
    budget = est.params_bytes + est.act_bytes + 3 * strip
    dense = sched.plan_serve_capacity(cfg, eng, max_seq, hbm_bytes=budget,
                                      budget_fraction=1.0, max_slots=64)
    paged = sched.plan_serve_capacity(cfg, eng, max_seq, paged=True,
                                      expected_seq=max_seq // 4,
                                      hbm_bytes=budget, budget_fraction=1.0,
                                      max_slots=64)
    assert paged.paged and paged.n_blocks > 0
    assert paged.n_microbatches > dense.n_microbatches
    # the paged estimate (pool, not strips) stays inside the same budget
    assert (sched.per_chip_bytes(cfg, paged, max_seq, train=False).total
            <= budget)
    # pool divides evenly over the data/pod partitions
    dp = paged.data_size * paged.pod_size
    assert paged.n_blocks % dp == 0
    # even a starvation budget must leave each partition able to back one
    # full max_seq request (the batcher hard-rejects in-spec traffic below)
    tiny = sched.plan_serve_capacity(cfg, eng, max_seq, paged=True,
                                     expected_seq=max_seq // 4, hbm_bytes=1,
                                     budget_fraction=1.0)
    per_row = -(-max_seq // tiny.block_size)
    assert tiny.n_blocks // dp >= per_row


def test_plan_serve_capacity_mix_sizes_a_gang():
    """A traffic mix plans a K-trial co-serving gang: K trial rows, per-trial
    pools, and a grid sized by the arrival-weighted expected lengths."""
    cfg = ASSIGNED_ARCHS["chatglm3-6b"].reduced()
    eng = base_eng()
    max_seq = 256
    est = sched.per_chip_bytes(cfg, dataclasses.replace(
        eng, n_trials=1, n_microbatches=1), max_seq, train=False)
    strip = eng.microbatch * max_seq * sched.kv_token_bytes_per_chip(cfg, eng)
    budget = 2 * est.params_bytes + est.act_bytes + 6 * strip
    mix = [(1.0, max_seq // 4), (1.0, max_seq // 4)]
    gang = sched.plan_serve_capacity(cfg, eng, max_seq, paged=True, mix=mix,
                                     hbm_bytes=budget, budget_fraction=1.0,
                                     max_slots=64)
    assert gang.n_trials == 2 and gang.paged and gang.n_blocks > 0
    # per-trial pool: K pools of n_blocks must fit the leftover budget
    dp = gang.data_size * gang.pod_size
    token_b = sched.kv_token_bytes_per_chip(cfg, gang)
    pool_bytes = 2 * (gang.n_blocks // dp) * gang.block_size * token_b
    assert 2 * est.params_bytes + est.act_bytes + pool_bytes <= budget
    # every (trial, shard) partition can still back one max_seq request
    per_row = -(-max_seq // gang.block_size)
    assert gang.n_blocks // dp >= per_row
    # skewing the weights toward a long-prompt arch shrinks the grid: the
    # weighted demand per row rises, so fewer cells fit the same pools
    skew = sched.plan_serve_capacity(
        cfg, eng, max_seq, paged=True,
        mix=[(3.0, max_seq), (1.0, max_seq // 8)],
        hbm_bytes=budget, budget_fraction=1.0, max_slots=64)
    assert skew.n_microbatches <= gang.n_microbatches
    # dense mix: K multiplies the per-trial strip cost, so the K=2 dense
    # gang fits at most as many slots per trial as the single-arch plan
    dense_one = sched.plan_serve_capacity(cfg, eng, max_seq,
                                          hbm_bytes=budget,
                                          budget_fraction=1.0, max_slots=64)
    dense_two = sched.plan_serve_capacity(cfg, eng, max_seq,
                                          mix=[(1.0, max_seq), (1.0, max_seq)],
                                          hbm_bytes=budget,
                                          budget_fraction=1.0, max_slots=64)
    assert dense_two.n_trials == 2
    assert dense_two.n_microbatches <= dense_one.n_microbatches
    with pytest.raises(ValueError):
        sched.plan_serve_capacity(cfg, eng, max_seq, mix=[])
    with pytest.raises(ValueError):
        sched.plan_serve_capacity(cfg, eng, max_seq, mix=[(-1.0, 8), (1.0, 8)])


def test_plan_serve_capacity_monotone_in_seq():
    """Longer caches can only reduce how many slots fit."""
    cfg = ASSIGNED_ARCHS["yi-34b"]  # full-size: memory bound actually binds
    eng = base_eng(n_stages=8, data_size=1, microbatch=1)
    slots = [sched.plan_serve_capacity(cfg, eng, max_seq=s).n_microbatches
             for s in (1024, 8192, 32768)]
    assert slots[0] >= slots[1] >= slots[2]
    for s, m in zip((1024, 8192, 32768), slots):
        planned = dataclasses.replace(eng, n_trials=1, n_microbatches=m,
                                      max_seq=s)
        assert (sched.per_chip_bytes(cfg, planned, s, train=False).total
                <= BUDGET or m == 1)


# ---------------------------------------------------------------------------
# simulator (paper Fig. 2)
# ---------------------------------------------------------------------------


def test_figure2_speedup_monotone_in_k():
    rows = sim.figure2_table(n_shards=8, n_models_list=(1, 2, 4, 8, 16),
                             n_microbatches=8)
    sp_mp = [r["speedup_vs_model_parallel"] for r in rows]
    sp_gp = [r["speedup_vs_gpipe"] for r in rows]
    # more concurrent models => more slots to fill the bubble with: the
    # speedup over (non-)pipelined model parallelism is nondecreasing in K
    for seq in (sp_mp, sp_gp):
        assert all(b >= a - 1e-9 for a, b in zip(seq, seq[1:])), seq
    # shard parallelism never loses to the gpipe baseline, and utilization
    # approaches 1 with K (the paper's central claim)
    assert all(s >= 1 - 1e-9 for s in sp_gp)
    utils = [r["shard_util"] for r in rows]
    assert all(b >= a - 1e-9 for a, b in zip(utils, utils[1:]))
    assert utils[-1] > 0.9
