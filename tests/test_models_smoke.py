"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step with output-shape and finiteness assertions, plus prefill+decode
consistency against the train-mode oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS
from repro.models import lm
from repro.models.frontend import synth_frontend_embeds, synth_mrope_positions
from repro.models.layers import ModelOptions

KEY = jax.random.PRNGKey(0)
B, S = 2, 24


def _batch(cfg, s=S):
    batch = {"tokens": jax.random.randint(KEY, (B, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (B, s), 0, cfg.vocab_size)}
    if cfg.frontend:
        batch["frontend_embeds"] = synth_frontend_embeds(cfg, KEY, B)
    if cfg.rope == "mrope":
        batch["mrope_pos"] = synth_mrope_positions(cfg, B, s)
    return batch


@pytest.mark.parametrize("name", sorted(ASSIGNED_ARCHS) + ["bert-large"])
def test_smoke_train_step(name):
    cfg = (ASSIGNED_ARCHS.get(name) or PAPER_ARCHS[name]).reduced()
    opts = ModelOptions()
    params = lm.init_params(cfg, KEY, max_pos=64)
    batch = _batch(cfg)
    logits, _, _ = lm.forward(cfg, opts, params, batch, mode="train")
    assert logits.shape == (B, S, cfg.vocab_size)
    loss, grads = jax.value_and_grad(
        lambda p: lm.loss_fn(cfg, opts, p, batch))(params)
    assert jnp.isfinite(loss)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", sorted(ASSIGNED_ARCHS))
def test_smoke_prefill_decode_consistency(name):
    cfg = ASSIGNED_ARCHS[name].reduced()
    # dropless capacity so MoE decode matches train exactly (capacity drops
    # are train-time semantics; see DESIGN.md)
    opts = ModelOptions(moe_capacity_factor=64.0)
    params = lm.init_params(cfg, KEY, max_pos=64)
    batch = _batch(cfg, 16)
    logits_full, _, _ = lm.forward(cfg, opts, params,
                                   {k: v for k, v in batch.items()
                                    if k != "labels"}, mode="train")
    sp = 8
    pre = {"tokens": batch["tokens"][:, :sp]}
    if cfg.frontend:
        pre["frontend_embeds"] = \
            batch["frontend_embeds"][:, :min(cfg.n_frontend_tokens, sp)]
    if cfg.rope == "mrope":
        pre["mrope_pos"] = batch["mrope_pos"][:, :, :sp]
    cache = lm.init_cache(cfg, B, 32, cache_dtype=jnp.float32)
    logits_pre, cache, _ = lm.forward(cfg, opts, params, pre, mode="prefill",
                                      cache=cache)
    errs = [float(jnp.max(jnp.abs(logits_pre - logits_full[:, :sp])))]
    for t in range(sp, 16):
        ld, cache, _ = lm.forward(
            cfg, opts, params, {"tokens": batch["tokens"][:, t:t + 1]},
            mode="decode", cache=cache,
            kv_offset=jnp.full((B,), t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(ld[:, 0] - logits_full[:, t]))))
    assert max(errs) < 5e-4, (name, errs)


def test_sliding_window_ring_buffer_decode():
    """Windowed decode with a ring-buffer cache must match full-cache decode
    restricted to the window (zamba2 long-context path)."""
    cfg = ASSIGNED_ARCHS["zamba2-7b"].reduced()
    opts = ModelOptions()
    params = lm.init_params(cfg, KEY, max_pos=64)
    toks = jax.random.randint(KEY, (B, 20), 0, cfg.vocab_size)
    w = 8
    # oracle: full cache, windowed attention via window arg in train mode
    logits_full, _, _ = lm.forward(cfg, opts, params, {"tokens": toks},
                                   mode="train", window=w)
    cache = lm.init_cache(cfg, B, 32, cache_dtype=jnp.float32, window=w)
    errs = []
    h = None
    for t in range(20):
        ld, cache, _ = lm.forward(cfg, opts, params,
                                  {"tokens": toks[:, t:t + 1]},
                                  mode="decode", cache=cache,
                                  kv_offset=jnp.full((B,), t, jnp.int32),
                                  window=w)
        errs.append(float(jnp.max(jnp.abs(ld[:, 0] - logits_full[:, t]))))
    assert max(errs) < 5e-4, errs


def test_mlp_paper_workload():
    from repro.configs import MLP_CONFIG
    params = lm.mlp_init(MLP_CONFIG, KEY)
    n = sum(x.size for x in jax.tree.leaves(params))
    assert abs(n - MLP_CONFIG.param_count()) < 10
    assert 1.1e6 < n < 1.3e6  # the paper's "1.2 million parameter" FFN
    x = jax.random.normal(KEY, (8, MLP_CONFIG.d_in))
    y = jax.random.randint(KEY, (8,), 0, MLP_CONFIG.d_out)
    loss = lm.mlp_loss(params, {"x": x, "y": y})
    assert jnp.isfinite(loss)
