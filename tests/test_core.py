"""Hydra core units: partitioner, scheduler, simulator, trials."""
import dataclasses

import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core import simulator as sim
from repro.core.partitioner import (balance_report, partition_costs,
                                    plan_stages)
from repro.core.pipeline import EngineConfig
from repro.core.scheduler import (max_concurrent_trials, per_chip_bytes,
                                  plan_gangs, replan_after_failure)
from repro.core.trials import SuccessiveHalving, TrialResult, grid_search, \
    random_search


BASE_ENG = EngineConfig(n_trials=1, n_microbatches=16, microbatch=1,
                        n_stages=16, data_size=16, fsdp=True)


# --------------------------------------------------------------------------
# partitioner
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(ASSIGNED_ARCHS))
def test_plan_stages_covers_all_layers(name):
    cfg = get_config(name)
    plan = plan_stages(cfg, 16)
    assert plan.padded_layers >= cfg.n_layers
    assert plan.padded_layers == 16 * plan.layers_per_stage
    total = sum(plan.real_layers_in_stage(s) for s in range(16))
    assert total == cfg.n_layers
    rep = balance_report(cfg, plan, 4096)
    # padding never worsens the tick bottleneck (max stage load)
    assert rep["imbalance"] <= plan.layers_per_stage


def test_partition_costs_dp_optimal():
    costs = [5, 1, 1, 1, 5, 1, 1, 1]
    starts = partition_costs(costs, 3)
    # reconstruct part sums
    bounds = starts + [len(costs)]
    parts = [sum(costs[bounds[i]:bounds[i + 1]]) for i in range(3)]
    assert max(parts) == 7  # optimal for this instance ([5,1],[1,1,5],[1,1,1])
    assert sum(parts) == sum(costs)


def test_partition_costs_matches_bruteforce():
    import itertools
    costs = [3, 1, 4, 1, 5, 9, 2, 6]
    k = 3

    def brute():
        best = float("inf")
        n = len(costs)
        for cuts in itertools.combinations(range(1, n), k - 1):
            b = (0,) + cuts + (n,)
            best = min(best, max(sum(costs[b[i]:b[i + 1]])
                                 for i in range(k)))
        return best

    starts = partition_costs(costs, k)
    bounds = starts + [len(costs)]
    got = max(sum(costs[bounds[i]:bounds[i + 1]]) for i in range(k))
    assert got == brute()


# --------------------------------------------------------------------------
# scheduler
# --------------------------------------------------------------------------


def test_capacity_planner_monotone_in_model_size():
    small = max_concurrent_trials(get_config("granite-moe-3b-a800m"),
                                  BASE_ENG, 4096)
    big = max_concurrent_trials(get_config("deepseek-67b"), BASE_ENG, 4096)
    assert small >= big >= 1


def test_memory_model_fsdp_shrinks_params():
    cfg = get_config("deepseek-67b")
    with_f = per_chip_bytes(cfg, BASE_ENG, 4096, train=True)
    without = per_chip_bytes(cfg, dataclasses.replace(BASE_ENG, fsdp=False),
                             4096, train=True)
    assert with_f.params_bytes < without.params_bytes


def test_gang_planning_covers_all_trials_and_bubble():
    trials = grid_search("chatglm3-6b", [1e-3, 3e-4], [0.0, 0.1], [0, 1])
    gangs = plan_gangs(trials, BASE_ENG, {"chatglm3-6b":
                                          get_config("chatglm3-6b")}, 4096)
    planned = [t for g in gangs for t in g.trials]
    assert sorted(t.tag for t in planned) == sorted(t.tag for t in trials)
    for g in gangs:
        assert g.engine.n_trials == len(g.trials)


def test_replan_after_failure_shrinks_data_axis():
    trials = grid_search("chatglm3-6b", [1e-3, 3e-4])
    cfgs = {"chatglm3-6b": get_config("chatglm3-6b")}
    gangs = plan_gangs(trials, BASE_ENG, cfgs, 4096)
    new = replan_after_failure(gangs, BASE_ENG, cfgs, 4096,
                               lost_data_rows=2)
    assert all(g.engine.data_size == 14 for g in new)
    assert sum(len(g.trials) for g in new) == len(trials)
    with pytest.raises(RuntimeError):
        replan_after_failure(gangs, BASE_ENG, cfgs, 4096, lost_data_rows=16)


# --------------------------------------------------------------------------
# simulator (the paper's Fig. 2)
# --------------------------------------------------------------------------


def test_traditional_model_parallel_utilization_is_1_over_s():
    for s in (4, 8):
        r = sim.simulate_model_parallel(2, s, n_microbatches=4)
        assert abs(r.utilization - 1.0 / s) < 1e-6


def test_shard_parallel_beats_model_parallel():
    for k in (2, 4, 8):
        sp = sim.simulate_shard_parallel(k, 8, 16)
        mp = sim.simulate_model_parallel(k, 8, 16)
        gp = sim.simulate_model_parallel(k, 8, 16, pipelined=True)
        assert sp.makespan < mp.makespan
        assert sp.makespan <= gp.makespan + 1e-9


def test_shard_parallel_utilization_increases_with_models():
    utils = [sim.simulate_shard_parallel(k, 8, 16).utilization
             for k in (1, 2, 4, 8)]
    assert all(b > a for a, b in zip(utils, utils[1:]))
    assert utils[-1] > 0.9  # paper D1: utilization -> 1


def test_closed_form_matches_simulator():
    for k, s, m in [(2, 4, 3), (4, 8, 2), (1, 16, 16)]:
        got = sim.simulate_shard_parallel(k, s, m).makespan
        want = sim.theoretical_shard_parallel_makespan(k, s, m)
        assert abs(got - want) < 1e-9, (k, s, m, got, want)


def test_figure2_table_speedups():
    rows = sim.figure2_table(n_shards=8, n_models_list=(4, 8))
    for r in rows:
        assert r["speedup_vs_model_parallel"] > 2.0  # vs paper Fig. 1 regime
        assert 0 < r["shard_util"] <= 1


# --------------------------------------------------------------------------
# trials / successive halving
# --------------------------------------------------------------------------


def test_grid_and_random_search_sizes():
    assert len(grid_search("a", [1, 2], [0.1], [0, 1])) == 4
    assert len(random_search("a", 7)) == 7


def test_successive_halving_selects_best():
    trials = grid_search("a", [1e-2, 3e-3, 1e-3, 3e-4])

    def fake_train(specs, n_steps):
        # quality improves with more steps; lr=1e-3 is secretly the best
        return [TrialResult(s, n_steps,
                            train_loss=abs(s.lr - 1e-3) + 1.0 / n_steps,
                            val_loss=abs(s.lr - 1e-3) + 1.0 / n_steps)
                for s in specs]

    best = SuccessiveHalving(base_steps=10, eta=2, max_rungs=3).run(
        trials, fake_train)
    assert best.spec.lr == 1e-3
