"""Preemptive retraction under overcommit > 1.0 and the host-offloaded
prefix cache: on a bursty trace the engine must retract running requests
instead of deadlocking, restore them through either path (host swap-in or
teacher-forced recompute), and keep every request's greedy tokens
bit-identical to the preemption-free schedule.

(Multi-device setup comes from tests/conftest.py — pytest-only module.)"""
import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs import ASSIGNED_ARCHS  # noqa: E402
from repro.core import pipeline as pl  # noqa: E402
from repro.core.partitioner import plan_stages  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402
from repro.models.layers import ModelOptions  # noqa: E402
from repro.serve import Request, ServeEngine  # noqa: E402

MAX_SEQ = 24


def build(arch="chatglm3-6b", n_stages=2, data_size=1, slots=2, microbatch=2,
          prefill_chunks=2, n_trials=1):
    cfg = ASSIGNED_ARCHS[arch].reduced()
    opts = ModelOptions()
    mesh = make_test_mesh(data_size, n_stages)
    eng = pl.EngineConfig(n_trials=n_trials, n_microbatches=slots,
                          microbatch=microbatch, n_stages=n_stages,
                          data_size=data_size, max_seq=MAX_SEQ,
                          cache_dtype=jnp.float32,
                          prefill_chunks=prefill_chunks)
    plan = plan_stages(cfg, eng.n_stages)
    params = pl.init_trial_params(cfg, eng, plan, jax.random.PRNGKey(0),
                                  max_pos=MAX_SEQ)
    return cfg, opts, mesh, eng, params


def bursty_trace(vocab, seed=7, n=6):
    """Everything arrives at t=0 — the workload that exhausts a small pool
    at once and forces the overcommitted engine to preempt."""
    rng = np.random.default_rng(seed)
    shapes = [(12, 5), (11, 6), (9, 4), (12, 6), (10, 5), (11, 4),
              (9, 6), (12, 4)][:n]
    return [Request(i, rng.integers(0, vocab, (p,)).astype(np.int32), g,
                    arrival=0.0)
            for i, (p, g) in enumerate(shapes)]


def _clone(reqs):
    return [r.clone() for r in reqs]


def _tokens(comps):
    return {c.rid: c.tokens for c in comps}


def _run_paged(cfg, eng, mesh, params, opts, reqs, overcommit,
               host_blocks, **kw):
    paged = dataclasses.replace(eng, paged=True, block_size=4, n_blocks=6)
    engine = ServeEngine(cfg, paged, mesh, params, opts,
                         overcommit=overcommit, host_blocks=host_blocks,
                         **kw)
    comps = engine.run(_clone(reqs), max_ticks=2000)
    return engine, comps


def test_overcommit_retraction_swap_restore_bit_identical():
    """overcommit 1.5 on a 6-block pool with a host tier: the engine must
    retract at least one running request, swap its KV out, restore it by
    swap-in, and finish every request with tokens identical to the
    preemption-free (overcommit 1.0) schedule — no deadlock, no leaks."""
    cfg, opts, mesh, eng, params = build()
    reqs = bursty_trace(cfg.vocab_size)
    base_engine, base = _run_paged(cfg, eng, mesh, params, opts, reqs,
                                   overcommit=1.0, host_blocks=0)
    oc_engine, oc = _run_paged(cfg, eng, mesh, params, opts, reqs,
                               overcommit=1.5, host_blocks=16)
    assert sorted(_tokens(oc)) == sorted(_tokens(base))  # nothing lost
    for rid, toks in _tokens(base).items():
        assert _tokens(oc)[rid] == toks, \
            f"request {rid}: overcommit 1.5 diverged from 1.0"
    s = oc_engine.stats
    assert s.retractions > 0, "pool never pressured — the test is vacuous"
    assert s.restored > 0 and s.restored <= s.retractions
    # the host tier was actually used for at least one restore
    assert s.swap_out_blocks > 0 and s.swap_in_blocks > 0
    assert base_engine.stats.retractions == 0  # 1.0 stays preemption-free
    assert oc_engine.allocator.all_free()
    assert oc_engine.store.host_used() == 0  # pinned payloads all consumed
    assert oc_engine.transfer.pending() == 0


def test_overcommit_retraction_recompute_restore_bit_identical():
    """No host tier: retraction must fall back to the teacher-forced replay
    (the final replay chunk re-derives the victim's last token — asserted
    bit-identical inside the engine) and still match the preemption-free
    schedule."""
    cfg, opts, mesh, eng, params = build()
    reqs = bursty_trace(cfg.vocab_size)
    _, base = _run_paged(cfg, eng, mesh, params, opts, reqs,
                         overcommit=1.0, host_blocks=0)
    engine, oc = _run_paged(cfg, eng, mesh, params, opts, reqs,
                            overcommit=1.5, host_blocks=0)
    for rid, toks in _tokens(base).items():
        assert _tokens(oc)[rid] == toks, \
            f"request {rid}: recompute-restore diverged"
    s = engine.stats
    assert s.retractions > 0 and s.restored > 0
    assert s.swap_in_blocks == 0  # no host tier => no swaps, only replay
    assert engine.allocator.all_free()


def test_overcommit_requires_paged():
    cfg, opts, mesh, eng, params = build()
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, eng, mesh, params, opts, overcommit=1.5)


def test_host_prefix_spill_exact_and_matchable():
    """Prefix cache over the tiered store: under pool pressure cached nodes
    spill to host instead of being destroyed, stay matchable, and a later
    request's hit restores them via swap-in — tokens stay bit-identical to
    the cache-off engine throughout."""
    cfg, opts, mesh, eng, params = build()
    rng = np.random.default_rng(3)
    base_prompt = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
    reqs = []
    for i in range(6):
        # shared 8-token prefix, 4-token distinct suffix; staggered arrivals
        # so the tree is pressured between hits (suffixes repeat: request 3+
        # can hit nodes that were spilled in the meantime)
        suffix = rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32) \
            if i < 3 else reqs[i - 3].prompt[8:]
        reqs.append(Request(i, np.concatenate([base_prompt, suffix]),
                            4 + i % 3, arrival=2.0 * i))
    _, plain = _run_paged(cfg, eng, mesh, params, opts, reqs,
                          overcommit=1.0, host_blocks=0)
    engine, cached = _run_paged(cfg, eng, mesh, params, opts, reqs,
                                overcommit=1.0, host_blocks=16,
                                prefix_cache=True)
    for rid, toks in _tokens(plain).items():
        assert _tokens(cached)[rid] == toks, \
            f"request {rid}: host-offloaded prefix cache changed tokens"
    s = engine.stats
    assert s.prefix_hits > 0 and s.prefix_hit_tokens > 0
    assert s.prefix_spills > 0, "pool pressure never spilled — resize"
    assert s.host_hit_tokens > 0, "no hit ever restored a spilled node"
    assert s.swap_in_blocks > 0
    # every device block still in use is a cached tree node (no slot leaks),
    # and every host block still resident is a spilled tree node
    assert engine.allocator.used_blocks() == \
        engine.prefix_cache.cached_blocks()
    assert engine.store.host_used() == \
        engine.prefix_cache.host_cached_blocks()


def test_no_spill_destroys_instead():
    """spill=False keeps the old destroy-on-evict semantics even with a
    host tier configured."""
    cfg, opts, mesh, eng, params = build()
    rng = np.random.default_rng(3)
    base_prompt = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
    reqs = [Request(i, np.concatenate(
                [base_prompt,
                 rng.integers(0, cfg.vocab_size, (4,)).astype(np.int32)]),
                    4, arrival=2.0 * i) for i in range(5)]
    engine, comps = _run_paged(cfg, eng, mesh, params, opts, reqs,
                               overcommit=1.0, host_blocks=16,
                               prefix_cache=True, spill=False)
    assert len(comps) == len(reqs)
    s = engine.stats
    assert s.prefix_spills == 0 and s.swap_out_blocks == 0
    assert engine.prefix_cache.evictions > 0  # pressure fell back to drops
    assert engine.allocator.used_blocks() == \
        engine.prefix_cache.cached_blocks()


@pytest.mark.slow
def test_overcommit_bursty_trace_heavy():
    """The full acceptance scenario at test scale: a larger bursty trace
    through overcommit 1.5 with prefix cache + host tier, against the
    preemption-free run — every request completes with identical tokens and
    both restore paths stay exercised."""
    cfg, opts, mesh, eng, params = build(slots=3)
    reqs = bursty_trace(cfg.vocab_size, seed=11, n=8)
    _, base = _run_paged(cfg, eng, mesh, params, opts, reqs,
                         overcommit=1.0, host_blocks=0)
    engine, oc = _run_paged(cfg, eng, mesh, params, opts, reqs,
                            overcommit=1.5, host_blocks=16,
                            prefix_cache=True)
    assert len(oc) == len(reqs)
    for rid, toks in _tokens(base).items():
        assert _tokens(oc)[rid] == toks, f"request {rid} diverged"
    s = engine.stats
    assert s.retractions > 0 and s.restored > 0
    assert engine.allocator.used_blocks() == \
        engine.prefix_cache.cached_blocks()
    assert engine.store.host_used() == \
        engine.prefix_cache.host_cached_blocks()
