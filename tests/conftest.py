"""Shared pytest configuration.

XLA fixes the host-platform device count at the first jax import, so the
fake-device flag must be set HERE — conftest imports before any test module,
which lets multi-device shard_map tests run inside the main pytest process
under a plain ``python -m pytest`` (no wrapper env needed).
"""
import os
import sys

_FLAG = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " "
                               + _FLAG).strip()

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# Markers (the `slow` tier split) and the tier-1 invocation live in
# pyproject.toml [tool.pytest.ini_options].
