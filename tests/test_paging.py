"""Paged KV-cache bookkeeping: allocator free-list discipline (reuse order,
atomic exhaustion, double-free rejection), block-table growth under chunked
prefill, and pool-exhaustion backpressure deferring batcher admission.

Host-side scheduling state only — no jax, runs in milliseconds. The
device-side half (scatter/gather through the tables) is covered by the paged
engine tests in test_serve_engine.py.
"""
import numpy as np
import pytest

from repro.serve import Batcher, BlockAllocator, BlockTable, Request, blocks_for


def mk_req(rid, prompt_len, gen, arrival=0.0):
    rng = np.random.default_rng(rid)
    return Request(rid, rng.integers(0, 100, (prompt_len,)).astype(np.int32),
                   gen, arrival=arrival)


# ---------------------------------------------------------------------------
# BlockAllocator
# ---------------------------------------------------------------------------


def test_alloc_free_reuse_order_is_fifo():
    a = BlockAllocator(n_blocks=5, block_size=4)
    first = a.alloc(2)
    assert first == [0, 1]
    assert a.alloc(1) == [2]
    a.free(first)  # 0, 1 go to the tail of the free list
    # oldest-first reuse: the untouched blocks come back before the recycled
    assert a.alloc(3) == [3, 4, 0]
    assert a.alloc(1) == [1]
    assert a.free_blocks() == 0 and a.used_blocks() == 5


def test_alloc_is_atomic_on_exhaustion():
    a = BlockAllocator(n_blocks=4, block_size=4)
    got = a.alloc(3)
    assert a.alloc(2) is None  # only 1 free: all-or-nothing, nothing taken
    assert a.free_blocks() == 1
    assert a.alloc(1) == [3]
    a.free(got + [3])
    assert a.free_blocks() == 4 and a.all_free()


def test_double_free_rejected():
    a = BlockAllocator(n_blocks=4, block_size=4)
    ids = a.alloc(2)
    a.free(ids)
    with pytest.raises(ValueError):
        a.free([ids[0]])
    with pytest.raises(ValueError):
        a.free([99])  # never-allocated id
    # the failed frees must not have corrupted the free list
    assert a.free_blocks() == 4 and a.all_free()


def test_partitioned_pool_ids_are_local():
    a = BlockAllocator(n_blocks=8, block_size=4, n_partitions=2)
    assert a.blocks_per_partition == 4
    # both partitions hand out the same local id range
    assert a.alloc(2, partition=0) == [0, 1]
    assert a.alloc(2, partition=1) == [0, 1]
    # partitions are independent: exhausting one leaves the other alone
    assert a.alloc(3, partition=0) is None
    assert a.alloc(2, partition=1) == [2, 3]
    assert a.free_blocks(0) == 2 and a.free_blocks(1) == 0
    with pytest.raises(ValueError):
        BlockAllocator(n_blocks=7, block_size=4, n_partitions=2)


# ---------------------------------------------------------------------------
# BlockTable (alloc-on-append / free-on-completion)
# ---------------------------------------------------------------------------


def test_table_growth_during_chunked_prefill():
    """ensure() grows exactly with the covered prefix as chunks append."""
    a = BlockAllocator(n_blocks=8, block_size=4)
    t = BlockTable(a)
    covered = 0
    for chunk_len in (5, 5, 5):  # 3 near-equal chunks of a 15-token prompt
        covered += chunk_len
        assert t.ensure(covered)
        assert t.n_blocks == blocks_for(covered, 4)
    assert t.n_blocks == 4 and t.capacity_tokens() == 16
    # idempotent for already-covered prefixes
    assert t.ensure(3) and t.n_blocks == 4
    row = t.as_row(max_blocks=6)
    assert row.tolist() == [0, 1, 2, 3, -1, -1]
    t.close()
    assert a.all_free()
    t.close()  # idempotent
    with pytest.raises(RuntimeError):
        t.ensure(1)


def test_table_growth_reports_exhaustion_without_partial_alloc():
    a = BlockAllocator(n_blocks=2, block_size=4)
    t = BlockTable(a)
    assert t.ensure(8)
    t2 = BlockTable(a)
    assert not t2.ensure(4)  # pool dry: stall signal, nothing allocated
    assert t2.n_blocks == 0
    t.close()
    assert t2.ensure(4)  # retry succeeds after blocks are freed
    t2.close()


# ---------------------------------------------------------------------------
# Batcher admission backpressure
# ---------------------------------------------------------------------------


def test_pool_exhaustion_defers_admission():
    """Free cells alone are not capacity: admission defers (FCFS) until the
    head request's exact block commitment fits the pool."""
    alloc = BlockAllocator(n_blocks=6, block_size=4)
    b = Batcher(n_microbatches=2, mb_global=2, prefill_chunks=2, max_seq=32,
                allocator=alloc)
    # total_len = 13 + 4 - 1 = 16 tokens -> 4 blocks committed per request
    for i in range(3):
        b.enqueue(mk_req(i, 13, 4))
    admitted = b.admit(now=1.0)
    # 4 + 4 > 6: only the head fits although 3 cells stay free
    assert [s.request.rid for s in admitted] == [0]
    assert b.occupied() == 1 and b.admit(now=2.0) == []
    # completion frees the commitment; the queue head moves in FCFS order
    admitted[0].release()
    assert alloc.all_free()
    assert [s.request.rid for s in b.admit(now=3.0)] == [1]


def test_small_later_request_does_not_jump_the_queue():
    alloc = BlockAllocator(n_blocks=6, block_size=4)
    b = Batcher(n_microbatches=2, mb_global=1, prefill_chunks=1, max_seq=32,
                allocator=alloc)
    b.enqueue(mk_req(0, 13, 4))  # 4 blocks
    b.enqueue(mk_req(1, 13, 4))  # 4 blocks -> deferred
    b.enqueue(mk_req(2, 3, 2))   # 1 block: would fit, but FCFS holds it back
    assert [s.request.rid for s in b.admit(now=1.0)] == [0]


def test_unservable_request_rejected_at_enqueue():
    alloc = BlockAllocator(n_blocks=4, block_size=4)
    b = Batcher(n_microbatches=2, mb_global=2, prefill_chunks=1, max_seq=64,
                allocator=alloc)
    with pytest.raises(ValueError):  # needs 5 blocks, partition holds 4
        b.enqueue(mk_req(0, 17, 2))
    # overcommit < 1 lowers the admission ceiling below the physical pool:
    # a request that fits the partition but not the limit must also be
    # rejected up front (admit() would defer it forever)
    tight = Batcher(n_microbatches=2, mb_global=2, prefill_chunks=1,
                    max_seq=64, allocator=BlockAllocator(8, 4),
                    overcommit=0.5)
    with pytest.raises(ValueError):  # needs 4 blocks, ceiling = 8*0.5 = 4...
        tight.enqueue(mk_req(1, 17, 4))  # 20 tokens -> 5 > 4
    tight.enqueue(mk_req(2, 13, 4))  # 16 tokens -> 4 <= 4: admissible
    assert [s.request.rid for s in tight.admit(now=1.0)] == [2]


# ---------------------------------------------------------------------------
# Truncation / speculative rollback
# ---------------------------------------------------------------------------


def snapshot(a, partition=0):
    return (list(a._free[partition]), dict(a._ref[partition]))


def test_truncate_restores_exact_allocator_state():
    """Growing a table for a rejected speculation and truncating back must
    leave the allocator bit-identical (free-list order AND refcounts) to
    never having grown — decref-based freeing would recycle through the
    tail and permute every later allocation."""
    a = BlockAllocator(n_blocks=8, block_size=4)
    t = BlockTable(a)
    assert t.ensure(9)  # 3 blocks committed (positions 0..8)
    before = snapshot(a)
    assert t.ensure(16)  # speculative growth: +1 block
    dropped = t.truncate(9)
    assert dropped == [3]
    assert snapshot(a) == before
    # the never-grown schedule and the grown-then-rolled-back schedule now
    # hand out identical ids
    assert a.alloc(2) == [3, 4]
    a.free([3, 4])
    t.close()
    assert a.all_free()


def test_truncate_then_regrow_returns_same_ids():
    a = BlockAllocator(n_blocks=6, block_size=4)
    t = BlockTable(a)
    assert t.ensure(12)
    grown = list(t.blocks)
    t.truncate(4)
    assert t.ensure(12)
    assert t.blocks == grown  # head-of-free-list restore: same ids, same order
    t.close()


def test_truncate_keeps_partial_tail_block():
    """Truncating to an offset inside a block keeps that block: its stale
    positions >= n_tokens are masked by kv_len on read and overwritten by
    the next append."""
    a = BlockAllocator(n_blocks=6, block_size=4)
    t = BlockTable(a)
    assert t.ensure(12)  # 3 blocks
    assert t.truncate(6) == [2]  # position 5 lives in block 1: keep 2 blocks
    assert t.n_blocks == 2 and t.capacity_tokens() == 8
    assert t.truncate(6) == []  # idempotent at the same offset
    t.close()
    with pytest.raises(RuntimeError):
        t.truncate(1)


def test_rollback_of_shared_block_rejected():
    """Only exclusively-owned blocks may roll back: a shared (incref'd)
    block has another holder whose view would be corrupted."""
    a = BlockAllocator(n_blocks=4, block_size=4)
    t = BlockTable(a)
    assert t.ensure(8)
    a.incref([t.blocks[-1]])  # a second holder adopts the tail block
    with pytest.raises(ValueError):
        t.truncate(4)
    # all-or-nothing: the failed rollback left table and refcounts intact
    assert t.n_blocks == 2 and a.ref_count(t.blocks[-1]) == 2
    a.decref([t.blocks[-1]])
    assert t.truncate(4) == [1]
    t.close()
    assert a.all_free()


def test_rollback_of_free_block_rejected():
    a = BlockAllocator(n_blocks=4, block_size=4)
    with pytest.raises(ValueError):
        a.rollback([0])  # never allocated: refcount 0


def test_store_rollback_rejects_in_flight_destination():
    """A pending transfer destination's bytes are not addressable, so it
    cannot have been written by the verify call being rolled back —
    un-allocating it would hand the destination to a new owner."""
    from repro.serve import BlockStore, make_null_transfer

    a = BlockAllocator(n_blocks=6, block_size=4)
    tr = make_null_transfer()
    store = BlockStore(a, host_blocks=0, transfer=tr)
    t = BlockTable(a, store=store)
    assert t.ensure(12)
    tr.copy(0, t.blocks[0], t.blocks[-1])  # tail block is a copy destination
    with pytest.raises(RuntimeError):
        t.truncate(4)
    assert t.n_blocks == 3  # nothing dropped
    tr._copies.clear()
    tr._in_flight.clear()  # transfer resolved (flush needs bound kernels)
    assert t.truncate(4) == [1, 2]
    assert store.rollbacks == 2
    t.close()
    assert a.all_free()


def test_truncate_leaves_cow_fork_untouched():
    """Speculation only ever truncates the private tail; a CoW-forked block
    in the retained prefix keeps its fresh id and refcount."""
    a = BlockAllocator(n_blocks=8, block_size=4)
    shared = BlockTable(a)
    assert shared.ensure(8)  # blocks [0, 1]
    t = BlockTable(a)
    t.seed(list(shared.blocks))
    a.incref(t.blocks)  # t adopts the shared prefix read-only
    assert t.ensure(16)  # + private blocks [2, 3]
    pairs = t.fork_shared(4, 8)  # writer forks the shared tail block
    assert pairs == [(1, 4)]
    assert t.truncate(12) == [3]  # rollback drops only the speculative tail
    assert t.blocks == [0, 4, 2]
    assert a.ref_count(4) == 1 and a.ref_count(1) == 1
    t.close()
    shared.close()
    assert a.all_free()


def test_truncate_property_interleaved_growth():
    """Property: any interleaving of ensure()/truncate() that returns to a
    given coverage leaves the allocator in the same state as growing
    straight to that coverage."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(st.lists(st.tuples(st.booleans(), st.integers(1, 40)),
                        min_size=1, max_size=12))
    @hyp.settings(deadline=None, max_examples=60)
    def run(ops):
        a = BlockAllocator(n_blocks=10, block_size=4)
        t = BlockTable(a)
        cover = 0
        for grow, n in ops:
            if grow:
                if t.ensure(n):
                    cover = max(cover, n)
            else:
                n = min(n, cover)
                t.truncate(n)
                cover = min(cover, max(n, 0))
        # reference: a fresh pool grown straight to the surviving coverage
        ref = BlockAllocator(n_blocks=10, block_size=4)
        rt = BlockTable(ref)
        assert rt.ensure(cover)
        assert t.blocks == rt.blocks
        assert snapshot(a) == snapshot(ref)
        t.close()
        rt.close()
        assert a.all_free() and ref.all_free()

    run()


def test_admission_balances_partitions():
    """Rows pick the partition with the fewest *committed* blocks (not the
    allocator's free count — same-round admissions have not allocated yet),
    so commitments spread instead of exhausting shard 0 while shard 1
    idles."""
    alloc = BlockAllocator(n_blocks=8, block_size=4, n_partitions=2)
    # mb_global=4, two rows per partition: both partitions offer free cells
    # with identical allocator free counts within one admit() round
    b = Batcher(n_microbatches=1, mb_global=4, prefill_chunks=1, max_seq=32,
                allocator=alloc, rows_per_partition=2)
    for i in range(2):
        b.enqueue(mk_req(i, 5, 4))  # 8 tokens -> 2 of 4 blocks per partition
    admitted = b.admit(now=1.0)
    assert len(admitted) == 2
    parts = sorted(b.partition_of(s.k, s.b) for s in admitted)
    assert parts == [0, 1]
    assert b.committed_blocks(0) == 2 and b.committed_blocks(1) == 2
