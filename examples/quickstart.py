"""Quickstart: Hydra shard-parallel training of two trials in one program.

Runs in <1 minute on a plain CPU (single device: the pipeline degenerates to
one stage but the full multi-trial machinery — slot stream, vocab-parallel
loss, per-trial optimizer — is exercised). For a real pipeline, relaunch with
XLA_FLAGS=--xla_force_host_platform_device_count=8.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import pipeline as pl
from repro.core.partitioner import plan_stages
from repro.data.pipeline import TrainBatches
from repro.launch.mesh import make_test_mesh
from repro.models.layers import ModelOptions
from repro.optim.adamw import AdamW

# use whatever devices exist: (data=1, model=N)
n_dev = jax.device_count()
n_stages = min(n_dev, 4)
mesh = make_test_mesh(1, n_stages)
print(f"devices: {n_dev}, pipeline stages: {n_stages}")

cfg = get_config("chatglm3-6b").reduced()  # tiny same-family model
opts = ModelOptions(remat=True)
eng = pl.EngineConfig(n_trials=2, n_microbatches=4, microbatch=2,
                      n_stages=n_stages, data_size=1)
plan = plan_stages(cfg, eng.n_stages)
params = pl.init_trial_params(cfg, eng, plan, jax.random.PRNGKey(0))
optimizer = AdamW(grad_clip=1.0)
opt_state = optimizer.init(params)
hparams = {"lr": jnp.asarray([3e-3, 1e-3]), "wd": jnp.asarray([0.0, 0.01])}

step_fn = pl.make_train_step(cfg, opts, eng, mesh, optimizer)
data = TrainBatches(cfg, eng, seq_len=32, seed=0)
for step in range(10):
    batch = data.batch_for_step(step)
    params, opt_state, metrics = step_fn(params, opt_state, batch, hparams,
                                         jnp.asarray(step, jnp.int32))
    losses = [f"{x:.4f}" for x in metrics["loss"]]
    print(f"step {step:2d}  per-trial loss {losses}  "
          f"grad_norm {[f'{x:.2f}' for x in metrics['grad_norm']]}")
data.close()
print("two models trained concurrently through one shard-parallel pipeline.")
