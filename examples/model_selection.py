"""End-to-end Hydra model selection: grid of trials over a ~100M-param LM,
trained shard-parallel with successive halving, checkpoint/restart enabled.

Production shape (8 pipeline stages × 100M params × 200+ steps):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/model_selection.py --steps 200

CI/CPU-quick shape:
    PYTHONPATH=src python examples/model_selection.py --tiny --steps 8
"""
import argparse
import json

import jax

from repro.configs.base import ArchConfig
from repro.core import pipeline as pl
from repro.core.hydra import HydraConfig, run_model_selection
from repro.core.trials import SuccessiveHalving, grid_search
from repro.launch.mesh import make_test_mesh
from repro.models.layers import ModelOptions


def make_model(tiny: bool) -> ArchConfig:
    if tiny:
        return ArchConfig(name="lm-tiny", family="dense", n_layers=4,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                          vocab_size=256, head_dim=16)
    # ~113M params: 12L × d768 × ff3072, 32k vocab
    return ArchConfig(name="lm-100m", family="dense", n_layers=12,
                      d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
                      vocab_size=32768, head_dim=64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--trials", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/hydra_selection_ckpt")
    args = ap.parse_args()

    cfg = make_model(args.tiny)
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")
    n_dev = jax.device_count()
    n_stages = min(4 if args.tiny else 8, n_dev)
    n_data = max(1, min(2, n_dev // n_stages))
    mesh = make_test_mesh(n_data, n_stages)
    print(f"mesh: data={n_data} × stages={n_stages}")

    eng = pl.EngineConfig(
        n_trials=args.trials, n_microbatches=4, microbatch=1,
        n_stages=n_stages, data_size=n_data, fsdp=not args.tiny,
        skip_bubbles=True, layer_remat=False)
    hc = HydraConfig(seq_len=args.seq_len or (32 if args.tiny else 256),
                     steps=args.steps, ckpt_dir=args.ckpt_dir,
                     checkpoint_every=max(args.steps // 4, 1))
    trials = grid_search(cfg.name, lrs=[3e-3, 1e-3, 3e-4, 1e-4])[:args.trials]
    strategy = SuccessiveHalving(base_steps=max(args.steps // 4, 2), eta=2)

    out = run_model_selection(cfg, ModelOptions(remat=True), mesh, hc,
                              trials, eng, strategy=strategy)
    print(json.dumps({
        "winner": out["best"].spec.tag,
        "winner_val_loss": round(out["best"].val_loss, 4),
        "leaderboard": sorted(
            [{"tag": r.spec.tag, "steps": r.steps,
              "val": round(r.val_loss, 4)} for r in out["all"]],
            key=lambda r: r["val"]),
    }, indent=1))


if __name__ == "__main__":
    main()
