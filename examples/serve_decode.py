"""Serving through the shard-parallel pipeline: a dynamic request stream is
continuously batched onto the pipeline's slots — slots recycle the round a
request finishes (the decode_32k cell's code path at toy scale).

    PYTHONPATH=src python examples/serve_decode.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_decode.py --n-model 4 --n-data 2
"""
import sys

from repro.launch import serve


def main():
    # thin veneer over the production serving driver (same code path)
    argv = sys.argv[1:]
    defaults = ["--arch", "chatglm3-6b", "--smoke", "--slots", "4",
                "--prompt-len", "12", "--gen-len", "6"]
    for flag in ("--arch", "--slots", "--prompt-len", "--gen-len"):
        if flag in argv:
            defaults = [d for i, d in enumerate(defaults)
                        if not (d == flag or (i > 0 and defaults[i - 1] == flag))]
    sys.argv = [sys.argv[0]] + defaults + argv
    serve.main()


if __name__ == "__main__":
    main()
