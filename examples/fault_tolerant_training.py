"""Fault tolerance demo: a chip failure mid-run, checkpoint/restart recovery
and an elastic re-plan of the gang around the cordoned mesh row — ending with
the bit-identical result an uninterrupted run would produce.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import pipeline as pl
from repro.core.partitioner import plan_stages
from repro.data.pipeline import TrainBatches
from repro.launch.mesh import make_test_mesh
from repro.models.layers import ModelOptions
from repro.optim.adamw import AdamW
from repro.runtime.elastic import MeshHealth, shrink_engine
from repro.runtime.fault_tolerance import LoopConfig, run_with_restarts

cfg = get_config("chatglm3-6b").reduced()
opts = ModelOptions(remat=True)
eng = pl.EngineConfig(n_trials=2, n_microbatches=2, microbatch=2,
                      n_stages=min(jax.device_count(), 2), data_size=1)
mesh = make_test_mesh(1, eng.n_stages)
plan = plan_stages(cfg, eng.n_stages)
optimizer = AdamW()
hparams = {"lr": jnp.asarray([1e-3, 3e-4]), "wd": jnp.zeros((2,))}
step_fn = pl.make_train_step(cfg, opts, eng, mesh, optimizer)
data = TrainBatches(cfg, eng, seq_len=16, seed=0)


def one_step(state, step):
    p, o = state
    p, o, m = step_fn(p, o, data.batch_for_step(step), hparams,
                      jnp.asarray(step, jnp.int32))
    return (p, o), m


def run(ckpt_dir, injector=None):
    params = pl.init_trial_params(cfg, eng, plan, jax.random.PRNGKey(0))
    return run_with_restarts(
        one_step, (params, optimizer.init(params)),
        LoopConfig(n_steps=8, checkpoint_every=2, ckpt_dir=ckpt_dir),
        failure_injector=injector)


with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
    clean = run(d1)

    armed = {"on": True}

    def chip_failure(step):
        if step == 5 and armed["on"]:
            armed["on"] = False
            raise RuntimeError("XLA device lost: chip (3, 7) is unhealthy")

    faulty = run(d2, injector=chip_failure)
    diff = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(clean.final_state[0]),
        jax.tree.leaves(faulty.final_state[0])))
    print(f"restarts: {faulty.restarts}; resumed and finished all "
          f"{8} steps; |params_faulty - params_clean| = {diff:.2e}")
    assert diff == 0.0, "restart must reproduce the uninterrupted run exactly"

# elastic re-plan: cordon one data row of the production mesh shape
health = MeshHealth.fresh(n_pods=1, n_data=16).cordon(0, 7)
eng16 = pl.EngineConfig(n_trials=4, n_microbatches=16, microbatch=1,
                        n_stages=16, data_size=16, fsdp=True)
shrunk = shrink_engine(eng16, health)
print(f"elastic: data axis 16 -> {shrunk.data_size} after cordoning row 7; "
      f"gangs re-planned, training resumes from the last checkpoint")
data.close()
print("FAULT TOLERANCE DEMO OK")
