"""Generate the data tables of EXPERIMENTS.md from results/dryrun JSONs.

Usage: PYTHONPATH=src python scripts/make_experiments.py > /tmp/tables.md
The narrative sections of EXPERIMENTS.md are hand-written; this emits the
§Dry-run and §Roofline tables plus per-variant comparisons for §Perf.
"""
import glob
import json
import os
import sys

RESULTS = "results/dryrun"


def cells(mesh, variant):
    out = {}
    for p in sorted(glob.glob(os.path.join(RESULTS, mesh, variant,
                                           "*.json"))):
        d = json.load(open(p))
        out[(d["arch"], d["shape"])] = d
    return out


def dryrun_table(mesh):
    base = cells(mesh, "baseline")
    lines = [
        f"| arch | shape | K | M | live GiB (CPU-BA) | modeled GiB | fits | "
        f"HLO GFLOP/dev | coll GB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (a, s), d in base.items():
        if "skipped" in d:
            lines.append(f"| {a} | {s} | — | — | — | — | skip | — | — | — |")
            continue
        e = d["engine"]
        h = d["hlo_costs"]
        lines.append(
            f"| {a} | {s} | {e['n_trials']} | {e['n_microbatches']} "
            f"| {d['per_device_live_bytes']/2**30:.1f} "
            f"| {d['modeled_bytes_per_device']/2**30:.1f} "
            f"| {'Y' if d['fits_16GB_modeled'] else 'N'} "
            f"| {h['flops_per_device']/1e9:,.0f} "
            f"| {h['collective_bytes_per_device']/1e9:.1f} "
            f"| {d['timings_s']['compile']} |")
    return "\n".join(lines)


def roofline_table(mesh="16x16", variant="baseline"):
    base = cells(mesh, variant)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "useful | roofline |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (a, s), d in base.items():
        if "skipped" in d:
            lines.append(f"| {a} | {s} | — | — | — | skip | — | — |")
            continue
        r = d["roofline"]
        lines.append(
            f"| {a} | {s} | {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['dominant']} "
            f"| {r['useful_ratio']:.3f} | **{r['roofline_fraction']:.4f}** |")
    return "\n".join(lines)


def variant_compare(mesh, arch, shape, variants):
    lines = [
        "| variant | compute s | memory s | collective s | dominant | "
        "useful | roofline | Δroofline |",
        "|---|---|---|---|---|---|---|---|",
    ]
    base_frac = None
    for v in variants:
        d = cells(mesh, v).get((arch, shape))
        if d is None or "skipped" in d:
            lines.append(f"| {v} | (missing) | | | | | | |")
            continue
        r = d["roofline"]
        if base_frac is None:
            base_frac = r["roofline_fraction"] or 1e-12
        ratio = r["roofline_fraction"] / base_frac
        lines.append(
            f"| {v} | {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['dominant']} "
            f"| {r['useful_ratio']:.3f} | {r['roofline_fraction']:.4f} "
            f"| ×{ratio:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### 16x16 dry-run\n")
        print(dryrun_table("16x16"))
        print("\n### 2x16x16 dry-run\n")
        print(dryrun_table("2x16x16"))
    if which in ("all", "roofline"):
        print("\n### roofline (16x16 baseline)\n")
        print(roofline_table())
    if which in ("all", "perf"):
        variants = sorted(os.path.basename(v) for v in
                          glob.glob(os.path.join(RESULTS, "16x16", "*")))
        for cell in sys.argv[2:]:
            a, s = cell.split("/")
            print(f"\n### {a} × {s}\n")
            print(variant_compare("16x16", a, s, variants))
