"""Recompute the roofline row of every cached dry-run JSON from its stored
HLO costs + engine config (no recompilation) — used when the roofline model
changes (e.g. the wall-clock factor for bubble-skipping engines)."""
import glob
import json
import sys

from repro.analysis import roofline as roof
from repro.analysis.hlo import HloCosts
from repro.configs import REGISTRY, SHAPES


def main(pattern="results/dryrun/*/*/*.json"):
    n = 0
    for path in glob.glob(pattern):
        d = json.load(open(path))
        if "skipped" in d:
            continue
        cfg = REGISTRY[d["arch"]]
        shape = SHAPES[d["shape"]]
        e = d["engine"]
        h = d["hlo_costs"]
        costs = HloCosts(
            flops=h["flops_per_device"],
            collective_bytes=h["collective_bytes_per_device"],
            hbm_bytes=h["hbm_bytes_per_device"],
            bytes_by_kind=h["bytes_by_kind"],
            count_by_kind=h["count_by_kind"])
        k = int(e["n_trials"])
        slots = k * int(e["n_microbatches"])
        ticks = slots + int(e["n_stages"]) - 1
        skip = e.get("skip_bubbles", "False") == "True"
        wall = ticks / slots if skip else 1.0
        rl = roof.from_hlo_costs(cfg, shape, d["mesh"], d["n_chips"], costs,
                                 n_trials=k, wall_factor=wall)
        d["roofline"] = rl.row()
        json.dump(d, open(path, "w"), indent=1)
        n += 1
    print(f"re-derived {n} cells")


if __name__ == "__main__":
    main(*(sys.argv[1:] or []))
