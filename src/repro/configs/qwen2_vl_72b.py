"""Qwen2-VL-72B — VLM; transformer BACKBONE only, M-RoPE.

The vision tower is a STUB per spec: ``input_specs()`` provides precomputed
patch embeddings that replace the first ``n_frontend_tokens`` positions, plus
3-section M-RoPE position ids (temporal/height/width).
[arXiv:2409.12191; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    rope="mrope",
    rope_theta=1_000_000.0,
    act="swiglu",
    frontend="vision",
    n_frontend_tokens=256,  # stubbed patch embeddings (dynamic-res upstream)
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-72B",
)
