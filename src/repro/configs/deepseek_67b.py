"""DeepSeek-67B — llama-arch dense decoder, GQA. [arXiv:2401.02954; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    head_dim=128,
    rope="1d",
    rope_theta=10_000.0,
    act="swiglu",
    source="arXiv:2401.02954; hf:deepseek-ai/deepseek-llm-67b-base",
)
