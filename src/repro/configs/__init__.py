"""Config registry: the 10 assigned architectures + the paper's own workloads.

``get_config(name)`` / ``list_archs()`` / ``SHAPES`` / ``input_specs`` are the
public entry points used by the launcher, tests and benchmarks.
"""
from repro.configs.base import (  # noqa: F401
    ArchConfig,
    HybridConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    input_specs,
    shape_applicable,
)

from repro.configs import (
    bert_large,
    chatglm3_6b,
    deepseek_67b,
    falcon_mamba_7b,
    granite_moe_3b_a800m,
    llama4_scout_17b_a16e,
    mlp_1m,
    musicgen_medium,
    qwen2_vl_72b,
    starcoder2_15b,
    yi_34b,
    zamba2_7b,
)

# The ten assigned architectures (dry-run + roofline cells).
ASSIGNED_ARCHS = {
    cfg.name: cfg
    for cfg in (
        yi_34b.CONFIG,
        starcoder2_15b.CONFIG,
        deepseek_67b.CONFIG,
        chatglm3_6b.CONFIG,
        musicgen_medium.CONFIG,
        falcon_mamba_7b.CONFIG,
        zamba2_7b.CONFIG,
        qwen2_vl_72b.CONFIG,
        granite_moe_3b_a800m.CONFIG,
        llama4_scout_17b_a16e.CONFIG,
    )
}

# The paper's own evaluation workloads.
PAPER_ARCHS = {
    bert_large.CONFIG.name: bert_large.CONFIG,
    mlp_1m.ARCH_VIEW.name: mlp_1m.ARCH_VIEW,
}

REGISTRY = {**ASSIGNED_ARCHS, **PAPER_ARCHS}

MLP_CONFIG = mlp_1m.CONFIG


def get_config(name: str) -> ArchConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(REGISTRY)}") from None


def list_archs(assigned_only: bool = False):
    return sorted(ASSIGNED_ARCHS if assigned_only else REGISTRY)
