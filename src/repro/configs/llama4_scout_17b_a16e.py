"""Llama-4-Scout-17B-16E — MoE decoder, 16 experts top-1, early fusion.

Implemented exactly as the assigned spec line (16 experts, top-1, d_ff 8192);
the production model's extra shared expert is intentionally omitted — noted in
DESIGN.md §4. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,  # per-expert FFN width
    vocab_size=202048,
    head_dim=128,
    rope="1d",
    rope_theta=500_000.0,
    act="swiglu",
    moe=MoEConfig(n_experts=16, top_k=1, expert_d_ff=8192),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
