"""1.2M-parameter feed-forward network — the paper's accuracy-parity workload.

Used by the exactness benchmark/tests: shard-parallel training of this model
must match single-device training bit-for-bit in math (paper desideratum D3).
Layout: 784 -> 512 -> 512 -> 512 -> 10  (~1.19M params, matching the paper's
"1.2 million parameter feedforward neural network").
"""
import dataclasses

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    name: str = "mlp-1m"
    family: str = "mlp"
    d_in: int = 784
    d_hidden: int = 512
    n_hidden: int = 3
    d_out: int = 10

    def param_count(self) -> int:
        # input projection + n_hidden residual-width layers + head ≈ 1.195M
        n = self.d_in * self.d_hidden + self.d_hidden
        for _ in range(self.n_hidden):
            n += self.d_hidden * self.d_hidden + self.d_hidden
        n += self.d_hidden * self.d_out + self.d_out
        return n


CONFIG = MLPConfig()

# ArchConfig view so the registry stays uniform (treated as 'mlp' family).
ARCH_VIEW = ArchConfig(
    name="mlp-1m", family="mlp", n_layers=4, d_model=512, n_heads=0,
    n_kv_heads=0, d_ff=512, vocab_size=0, rope="none",
    source="paper §4 workload",
)
