"""MusicGen-medium — decoder-only transformer over EnCodec audio tokens.

Backbone only (per spec): the EnCodec/conditioning frontend is a STUB —
``input_specs()`` supplies precomputed frame embeddings for the conditioning
prefix; the sequence itself is EnCodec codes (vocab 2048).
[arXiv:2306.05284; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,  # MHA (GQA with kv == heads)
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    rope="learned",  # musicgen uses sinusoidal/learned positions, not rotary
    act="gelu",
    frontend="audio",
    n_frontend_tokens=64,  # stubbed conditioning frames
    source="arXiv:2306.05284; hf:facebook/musicgen-medium",
)
