"""Falcon-Mamba-7B — attention-free Mamba1 architecture.
[arXiv:2410.05355; unverified]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,  # attention-free, no FFN sub-block (mamba block is the mixer+ffn)
    vocab_size=65024,
    rope="none",
    ssm=SSMConfig(kind="mamba1", d_state=16, d_conv=4, expand=2, dt_rank=256),
    source="arXiv:2410.05355; hf:tiiuae/falcon-mamba-7b",
)
