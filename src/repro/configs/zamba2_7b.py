"""Zamba2-7B — hybrid: Mamba2 backbone + *shared* attention block.
[arXiv:2411.15242; unverified]

The shared attention+MLP block (weights shared across applications) is applied
after every 6th backbone layer. For long_500k the shared block uses a 4096
sliding window so the cache stays O(window), keeping the arch sub-quadratic.
"""
from repro.configs.base import ArchConfig, HybridConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,  # shared block is MHA
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,
    rope="1d",
    ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2,
                  head_dim=64, n_groups=2, chunk_size=256),
    hybrid=HybridConfig(attn_every=6, shared_d_ff=14336),
    sliding_window=4096,  # used for long_500k only (see models/blocks.py)
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-7B",
)
