"""Architecture + shape configuration dataclasses for the Hydra framework.

Every assigned architecture is expressed as an :class:`ArchConfig`; input-shape
cells (train_4k / prefill_32k / decode_32k / long_500k) are :class:`ShapeConfig`.
``input_specs`` builds the ShapeDtypeStruct stand-ins used by the multi-pod
dry-run (no device allocation).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (per-layer FFN experts)."""

    n_experts: int
    top_k: int
    expert_d_ff: int
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-family state-space block configuration."""

    kind: str  # "mamba1" | "mamba2"
    d_state: int
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # mamba1 only; 0 -> ceil(d_model / 16)
    head_dim: int = 64  # mamba2 only
    n_groups: int = 1  # mamba2 only
    chunk_size: int = 256  # mamba2 chunked-scan block size

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank > 0 else math.ceil(d_model / 16)

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba-style hybrid: SSM backbone with a *shared* attention block."""

    attn_every: int  # apply the shared attention block after every N layers
    shared_d_ff: int  # MLP width inside the shared block


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm", "encoder", "mlp")
ROPE_KINDS = ("1d", "2d", "mrope", "none", "learned")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    rope: str = "1d"
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    act: str = "swiglu"  # "swiglu" | "gelu"
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    frontend: Optional[str] = None  # "audio" | "vision" (stub modality input)
    n_frontend_tokens: int = 0  # positions replaced by precomputed embeddings
    sliding_window: int = 0  # 0 = full attention; >0 = window (long-context)
    source: str = ""  # provenance note

    # -- derived ------------------------------------------------------------
    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.rope not in ROPE_KINDS:
            raise ValueError(f"unknown rope kind {self.rope!r}")
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_causal_lm(self) -> bool:
        return self.family not in ("encoder", "mlp")

    @property
    def supports_long_context(self) -> bool:
        """long_500k runs only for sub-quadratic sequence mixers (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    # -- parameter counting (used by memory model + MODEL_FLOPS) -------------
    def layer_param_count(self) -> int:
        d, f = self.d_model, self.d_ff
        if self.family == "ssm":
            s = self.ssm
            di = s.d_inner(d)
            if s.kind == "mamba1":
                r = s.resolved_dt_rank(d)
                return (
                    d * 2 * di  # in_proj
                    + di * s.d_conv + di  # conv
                    + di * (r + 2 * s.d_state)  # x_proj
                    + r * di + di  # dt_proj (+bias)
                    + di * s.d_state + di  # A_log, D
                    + di * d  # out_proj
                    + d  # norm
                )
            raise ValueError("ssm family expects mamba1")
        if self.family == "hybrid":
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_ssm_heads(d)
            g = s.n_groups
            conv_dim = di + 2 * g * s.d_state
            return (
                d * (2 * di + 2 * g * s.d_state + nh)  # in_proj (mamba2)
                + conv_dim * s.d_conv + conv_dim  # conv
                + 3 * nh  # A_log, D, dt_bias
                + di  # gated norm
                + di * d  # out_proj
                + d  # pre-norm
            )
        # attention sub-block
        attn = self.d_model * self.head_dim * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * self.head_dim * self.d_model
        if self.moe is not None:
            e, ff = self.moe.n_experts, self.moe.expert_d_ff
            ffn = self.d_model * self.moe.n_experts  # router
            ffn += e * (2 * self.d_model * ff + ff * self.d_model)
        elif self.act == "swiglu":
            ffn = 3 * self.d_model * f
        else:
            ffn = 2 * self.d_model * f
        norms = 2 * self.d_model
        return attn + ffn + norms

    def shared_block_param_count(self) -> int:
        if self.hybrid is None:
            return 0
        attn = self.d_model * self.head_dim * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * self.head_dim * self.d_model
        ffn = 3 * self.d_model * self.hybrid.shared_d_ff
        return attn + ffn + 2 * self.d_model

    def param_count(self) -> int:
        n = self.n_layers * self.layer_param_count()
        n += self.shared_block_param_count()
        n += self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model  # head
        n += self.d_model  # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts) — for 6·N_active·D."""
        if self.moe is None:
            return self.param_count()
        e, k, ff = self.moe.n_experts, self.moe.top_k, self.moe.expert_d_ff
        dense_experts_per_layer = e * 3 * self.d_model * ff
        active_experts_per_layer = k * 3 * self.d_model * ff
        return self.param_count() - self.n_layers * (
            dense_experts_per_layer - active_experts_per_layer
        )

    # -- reduced config for CPU smoke tests ----------------------------------
    def reduced(self) -> "ArchConfig":
        """Same-family tiny config: a few layers, narrow width, tiny vocab."""
        kw: dict = dict(
            name=self.name + "-smoke",
            family=self.family,
            n_layers=min(self.n_layers, 4),
            d_model=64,
            n_heads=4 if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128 if self.d_ff else 0,
            vocab_size=128,
            head_dim=16 if self.n_heads else 0,
            rope=self.rope,
            rope_theta=self.rope_theta,
            act=self.act,
            tie_embeddings=self.tie_embeddings,
            frontend=self.frontend,
            n_frontend_tokens=min(self.n_frontend_tokens, 4),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            source="smoke",
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(n_experts=4, top_k=min(self.moe.top_k, 2),
                                  expert_d_ff=32)
            kw["d_ff"] = 32
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(kind=self.ssm.kind, d_state=8, d_conv=4,
                                  expand=2, dt_rank=4, head_dim=16, n_groups=1,
                                  chunk_size=8)
        if self.hybrid is not None:
            kw["hybrid"] = HybridConfig(attn_every=2, shared_d_ff=64)
            kw["n_layers"] = 5
        return ArchConfig(**kw)


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs; (False, reason) marks a recorded skip."""
    if shape.name == "long_500k" and not arch.supports_long_context:
        return False, "pure full-attention arch: 500k dense-causal decode is " \
                      "quadratic-cost; sub-quadratic mixing required (DESIGN.md §4)"
    if not arch.is_causal_lm and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    return True, ""


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(arch: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell.

    train:   tokens + labels over the full sequence
    prefill: tokens (cache is an *output* of prefill)
    decode:  one new token per sequence + the live cache/state is threaded by
             the engine (its specs come from the model's ``state_specs``)
    Modality frontends (audio/vlm) additionally receive precomputed embeddings
    for ``n_frontend_tokens`` positions, and M-RoPE position ids for vlm.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: dict = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    else:  # decode: one token per sequence, against a cache of length s
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
        specs["position"] = jax.ShapeDtypeStruct((b,), i32)
    if arch.frontend is not None and shape.kind != "decode":
        nf = arch.n_frontend_tokens
        specs["frontend_embeds"] = jax.ShapeDtypeStruct((b, nf, arch.d_model), dtype)
    if arch.rope == "mrope" and shape.kind != "decode":
        specs["mrope_pos"] = jax.ShapeDtypeStruct((3, b, s), i32)
    return specs
