"""ChatGLM3-6B — dense decoder, 2d (interleaved-half) RoPE, GQA kv=2.
[arXiv:2406.12793; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    head_dim=128,
    rope="2d",  # ChatGLM applies rotary to half the head dims (2d scheme)
    rope_theta=10_000.0,
    act="swiglu",
    source="arXiv:2406.12793; hf:THUDM/chatglm3-6b",
)
