"""StarCoder2-15B — dense decoder, GQA, RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    rope="1d",
    rope_theta=100_000.0,
    act="gelu",
    source="arXiv:2402.19173; hf:bigcode/starcoder2-15b",
)
