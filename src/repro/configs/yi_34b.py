"""Yi-34B — llama-arch dense decoder with GQA. [arXiv:2403.04652; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    rope="1d",
    rope_theta=5_000_000.0,
    act="swiglu",
    source="arXiv:2403.04652; hf:01-ai/Yi-34B",
)
