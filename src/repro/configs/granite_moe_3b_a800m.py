"""Granite-MoE 3B-a800m — MoE decoder, 40 experts top-8, GQA.
[hf:ibm-granite/granite-3.0-1b-a400m-base family; hf]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,  # per-expert FFN width
    vocab_size=49155,
    head_dim=64,
    rope="1d",
    act="swiglu",
    moe=MoEConfig(n_experts=40, top_k=8, expert_d_ff=512),
    source="hf:ibm-granite/granite-3.0-3b-a800m-base",
)
