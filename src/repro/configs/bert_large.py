"""BERT-Large — the paper's own heavy workload (§4: SQuAD fine-tune, 4×V100).

Encoder-only; used by ``benchmarks/bench_memory.py`` to reproduce the paper's
"3× per-device memory reduction under 4-way model parallelism" measurement.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="bert-large",
    family="encoder",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=30522,
    head_dim=64,
    rope="learned",
    act="gelu",
    source="arXiv:1810.04805 (paper workload)",
)
