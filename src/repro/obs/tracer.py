"""Structured tracing: typed events with engine-tick + wall timestamps.

Two implementations share one interface:

* :class:`Tracer` — records events in memory for the exporters
  (``obs.export``: JSONL + Chrome trace-event/Perfetto) and the span
  validator (``obs.validate``).
* :class:`NullTracer` — the module-level :data:`NULL_TRACER` singleton the
  engine holds when tracing is off. Every method is a no-op and
  ``enabled`` is False, so hot emission sites guard with
  ``if tracer.enabled:`` and pay one attribute read + branch per *site*
  (not per event) — no kwargs dict is ever built on the disabled path.

Event taxonomy (the ``ev`` field):

Per-request lifecycle (all carry ``rid``):
  ``enqueue`` → ``admit`` (cell ``k,m,b``; ``prefix_hit`` rides alongside
  when admission matched cached blocks) → ``prefill_chunk``* →
  ``first_token`` → [``retract`` (``via`` = swap|recompute) →
  ``swap_out`` → ``restore``]* → [``spec_propose`` → ``spec_verify`` →
  ``rollback``]* → ``complete``.

Per-round engine records: ``round`` — call-mode mix, mixed-wave fill,
pool blocks in use, per-partition host-tier depth, transfer in-flight
peak, per-arch queue depths, slot occupancy.

Subsystem instants: ``prefix_spill`` / ``prefix_evict`` /
``host_evict`` (tiered store + radix cache), ``compile`` (first sight of
a (mode, token shape, table bucket) pipeline-program signature).

Search spans: ``span_begin`` / ``span_end`` (``name`` = gang | rung)
with wall timestamps — the successive-halving timeline of ``core.hydra``.

Timestamps: ``tick`` is the engine round (set once per round via
:meth:`begin_tick`; emission sites never thread it), ``wall`` is seconds
since the tracer was constructed. Search spans are wall-only
(``tick`` = -1 outside an engine round).
"""
from __future__ import annotations

import time
from typing import Optional


class Tracer:
    """In-memory structured event recorder. See the module docstring for
    the event taxonomy; exporters live in ``obs.export``."""

    enabled = True

    def __init__(self):
        self.events: list = []
        self.tick = -1  # current engine round; -1 = outside any round
        self._t0 = time.monotonic()

    # -- timestamps ----------------------------------------------------------

    def begin_tick(self, tick: int) -> None:
        """Set the engine-tick timestamp for every event until the next
        round (so per-event emission never threads the tick)."""
        self.tick = tick

    def _wall(self) -> float:
        return time.monotonic() - self._t0

    # -- emission ------------------------------------------------------------

    def emit(self, ev: str, **fields) -> None:
        fields["ev"] = ev
        fields["tick"] = self.tick
        fields["wall"] = round(self._wall(), 6)
        self.events.append(fields)

    def req(self, ev: str, rid: int, **fields) -> None:
        """Per-request lifecycle event."""
        self.emit(ev, rid=rid, **fields)

    def round(self, **fields) -> None:
        """Per-round engine record (one per engine tick while tracing)."""
        self.emit("round", **fields)

    def compile(self, mode: str, **fields) -> None:
        """First sight of a pipeline-program shape signature — each one is
        an XLA compile the serving timeline should show."""
        self.emit("compile", mode=mode, **fields)

    def span_begin(self, name: str, **fields) -> None:
        self.emit("span_begin", name=name, **fields)

    def span_end(self, name: str, **fields) -> None:
        self.emit("span_end", name=name, **fields)

    # -- management ----------------------------------------------------------

    def clear(self) -> None:
        self.events = []
        self.tick = -1
        self._t0 = time.monotonic()

    def __len__(self) -> int:
        return len(self.events)


class NullTracer:
    """The disabled path: ``enabled`` False, every method a no-op. Hot
    sites guard event construction with ``if tracer.enabled:`` so the only
    per-round cost when tracing is off is the attribute read + branch."""

    enabled = False
    events: list = []  # always empty; shared on purpose (never appended)
    tick = -1

    def begin_tick(self, tick: int) -> None:
        pass

    def emit(self, ev: str, **fields) -> None:
        pass

    def req(self, ev: str, rid: int, **fields) -> None:
        pass

    def round(self, **fields) -> None:
        pass

    def compile(self, mode: str, **fields) -> None:
        pass

    def span_begin(self, name: str, **fields) -> None:
        pass

    def span_end(self, name: str, **fields) -> None:
        pass

    def clear(self) -> None:
        pass

    def __len__(self) -> int:
        return 0


NULL_TRACER = NullTracer()


def resolve(tracer: Optional[Tracer]):
    """``tracer or NULL_TRACER`` with the None-vs-disabled distinction kept
    explicit at construction sites."""
    return tracer if tracer is not None else NULL_TRACER


__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "resolve"]
