"""Typed metrics: counters, gauges, and bounded-reservoir histograms.

``ServeStats`` grew one ad-hoc int per PR and one unbounded ``*_samples``
list per latency distribution — O(ticks) host memory for the life of a run,
and no way for an exporter to discover what exists. :class:`MetricRegistry`
puts every metric behind one of three typed primitives:

* :class:`Counter` — a monotone-ish numeric cell (``+=`` per event);
* :class:`Gauge`   — a last-value cell (peaks, wall clocks);
* :class:`Reservoir` — a bounded histogram: exact ``count``/``total``/
  ``min_value``/``max_value`` plus an Algorithm-R uniform sample capped at
  ``cap`` values, so percentile queries stay O(cap) while the run streams
  millions of observations.

The reservoir is list-compatible on purpose: ``append``/``len``/``iter``/
``max()``/``np.mean`` all behave like the list it replaces, and while the
observation count is below ``cap`` (every tier-1 test and smoke bench) the
sample IS the full population — percentiles and means are bit-identical to
the unbounded implementation. The RNG is seeded per metric name, so runs
are deterministic regardless of host entropy.
"""
from __future__ import annotations

import random
import zlib
from typing import Dict, Optional

import numpy as np

DEFAULT_RESERVOIR_CAP = 4096


class Counter:
    """Monotone-ish numeric cell (the registry allows ``=`` for syncs from
    subsystem-owned counters, e.g. the prefix cache's)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value=0):
        self.name = name
        self.value = value


class Gauge:
    """Last-value cell (peaks, accumulated wall seconds)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value=0.0):
        self.name = name
        self.value = value


class Reservoir:
    """Bounded histogram: exact count/sum/min/max + Algorithm-R sample.

    Below ``cap`` observations the sample is the full population (queries
    are exact); past it, each new value replaces a uniformly random slot
    with probability cap/count, so the sample stays uniform over the whole
    stream while memory stays O(cap).
    """

    __slots__ = ("name", "cap", "count", "total", "min_value", "max_value",
                 "_samples", "_rng")

    def __init__(self, name: str = "", cap: int = DEFAULT_RESERVOIR_CAP):
        if cap < 1:
            raise ValueError(f"reservoir cap must be >= 1, got {cap}")
        self.name = name
        self.cap = cap
        self.count = 0
        self.total = 0.0
        self.min_value: Optional[float] = None
        self.max_value: Optional[float] = None
        self._samples: list = []
        # deterministic per-metric stream: same run -> same sample set
        self._rng = random.Random(zlib.crc32(name.encode()) or 1)

    def append(self, x) -> None:
        x = float(x)
        self.count += 1
        self.total += x
        if self.min_value is None or x < self.min_value:
            self.min_value = x
        if self.max_value is None or x > self.max_value:
            self.max_value = x
        if len(self._samples) < self.cap:
            self._samples.append(x)
        else:  # Algorithm R: uniform over all count observations
            j = self._rng.randrange(self.count)
            if j < self.cap:
                self._samples[j] = x

    def extend(self, xs) -> None:
        for x in xs:
            self.append(x)

    # -- list compatibility (drop-in for the unbounded sample lists) ---------

    def __len__(self) -> int:
        return self.count  # observations seen, not sample slots held

    def __bool__(self) -> bool:
        return self.count > 0

    def __iter__(self):
        return iter(self._samples)

    def __array__(self, dtype=None, copy=None):
        return np.asarray(self._samples, dtype=dtype)

    def __repr__(self) -> str:
        return (f"Reservoir({self.name!r}, count={self.count}, "
                f"mean={self.mean_value:.4g})")

    # -- queries -------------------------------------------------------------

    @property
    def mean_value(self) -> float:
        """Exact mean over ALL observations (``np.mean`` on the reservoir
        averages the bounded sample instead — named ``mean_value`` rather
        than ``mean`` so numpy's protocol lookup doesn't find a float
        attribute and falls through to ``__array__``)."""
        return self.total / self.count if self.count else 0.0

    @property
    def samples(self) -> list:
        return list(self._samples)

    def percentile(self, q) -> float:
        """Exact while count <= cap; reservoir-estimated past it."""
        if not self._samples:
            return 0.0
        return float(np.percentile(
            np.asarray(self._samples, np.float64), q))

    def snapshot(self) -> dict:
        return {"count": self.count, "sum": self.total,
                "min": self.min_value, "max": self.max_value,
                "mean": round(self.mean_value, 6),
                "p50": round(self.percentile(50), 6),
                "p95": round(self.percentile(95), 6),
                "p99": round(self.percentile(99), 6)}


class MetricRegistry:
    """Named typed metrics with an export-friendly snapshot.

    One registry per engine run; ``ServeStats`` fronts one so legacy
    attribute access (``stats.calls += 1``) routes here unchanged.
    """

    def __init__(self, reservoir_cap: int = DEFAULT_RESERVOIR_CAP):
        self.reservoir_cap = reservoir_cap
        self._metrics: Dict[str, object] = {}

    def counter(self, name: str, value=0) -> Counter:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Counter(name, value)
        return m

    def gauge(self, name: str, value=0.0) -> Gauge:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Gauge(name, value)
        return m

    def histogram(self, name: str,
                  cap: Optional[int] = None) -> Reservoir:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = Reservoir(
                name, cap if cap is not None else self.reservoir_cap)
        return m

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def get(self, name: str):
        return self._metrics[name]

    def names(self) -> list:
        return sorted(self._metrics)

    def value(self, name: str):
        """Counter/gauge -> the number; histogram -> the Reservoir itself
        (so legacy ``stats.ttft_samples.append(...)`` keeps working)."""
        m = self._metrics[name]
        if isinstance(m, Reservoir):
            return m
        return m.value

    def set_value(self, name: str, value) -> None:
        m = self._metrics[name]
        if isinstance(m, Reservoir):
            raise TypeError(f"histogram {name!r} takes append(), not =")
        m.value = value

    def snapshot(self) -> dict:
        """{name: value-or-histogram-summary} for the metrics exporter."""
        out = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            out[name] = m.snapshot() if isinstance(m, Reservoir) else m.value
        return out


__all__ = ["Counter", "Gauge", "Reservoir", "MetricRegistry",
           "DEFAULT_RESERVOIR_CAP"]
