"""Trace exporters: JSONL event logs and Chrome trace-event/Perfetto JSON.

JSONL is the lossless interchange format — one event dict per line,
``read_events`` round-trips ``write_events`` exactly, and the span
validator (``obs.validate``) consumes either the in-memory list or a
reloaded file interchangeably.

The Perfetto export renders the engine timeline the way the paper argues
about utilization — as tracks you can see idle gaps on:

* **serve cells** (pid 1) — one track per (k, m, b) slot cell. A request's
  residency is a duration slice from its ``admit``/``restore`` round to its
  ``complete``/``retract`` round, named ``req <rid>``; ``prefill_chunk``
  slices nest inside it; ``first_token`` / ``retract`` / ``restore`` /
  ``rollback`` are instant markers on the cell's track.
* **pool** (pid 2) — counter tracks: device blocks in use, per-partition
  host-tier depth, transfer in-flight peak; ``prefix_spill`` /
  ``prefix_evict`` / ``host_evict`` instants.
* **queues** (pid 3) — one per-arch queue-depth counter track, with
  ``enqueue`` instants.
* **compile** (pid 4) — one instant per first-seen pipeline-program shape
  signature (mode × token width × table bucket).
* **search** (pid 5) — ``span_begin``/``span_end`` pairs (hydra gangs and
  successive-halving rungs) as wall-clock duration slices.

Engine events are timestamped in *ticks* (1 tick rendered as
``TICK_US`` µs — the deterministic scheduling unit); search spans are
wall-clock. Perfetto displays both; cross-domain alignment is not
meaningful and not implied.
"""
from __future__ import annotations

import json

TICK_US = 1000  # one engine round rendered as 1ms of trace time

_PID_CELLS, _PID_POOL, _PID_QUEUES, _PID_COMPILE, _PID_SEARCH = 1, 2, 3, 4, 5

# per-request instant markers rendered on the owning cell's track
_CELL_INSTANTS = ("first_token", "retract", "restore", "rollback",
                  "spec_verify", "prefix_hit")
_POOL_INSTANTS = ("prefix_spill", "prefix_evict", "host_evict")


def write_events(events, path: str) -> int:
    """One JSON object per line; returns the number of events written."""
    with open(path, "w") as f:
        for ev in events:
            f.write(json.dumps(ev, sort_keys=True))
            f.write("\n")
    return len(events)


def read_events(path: str) -> list:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def write_metrics(snapshot: dict, path: str) -> int:
    """Flatten a ``MetricRegistry.snapshot()`` (or any flat dict) to JSONL:
    one ``{"metric": name, "value"/"hist": ...}`` record per line."""
    n = 0
    with open(path, "w") as f:
        for name in sorted(snapshot):
            v = snapshot[name]
            rec = ({"metric": name, "hist": v} if isinstance(v, dict)
                   else {"metric": name, "value": v})
            f.write(json.dumps(rec, sort_keys=True))
            f.write("\n")
            n += 1
    return n


# -- Chrome trace-event / Perfetto ------------------------------------------


def _meta(pid, name, tid=None):
    if tid is None:
        return {"ph": "M", "pid": pid, "name": "process_name",
                "args": {"name": name}}
    return {"ph": "M", "pid": pid, "tid": tid, "name": "thread_name",
            "args": {"name": name}}


def _counter(pid, ts, name, value):
    return {"ph": "C", "pid": pid, "tid": 0, "ts": ts, "name": name,
            "args": {name: value}}


def _instant(pid, tid, ts, name, args):
    return {"ph": "i", "pid": pid, "tid": tid, "ts": ts, "s": "t",
            "name": name, "args": args}


def _slice(pid, tid, ts, dur, name, args):
    return {"ph": "X", "pid": pid, "tid": tid, "ts": ts, "dur": dur,
            "name": name, "args": args}


def _args(ev, drop=("ev", "tick", "wall", "rid", "k", "m", "b")):
    return {k: v for k, v in ev.items() if k not in drop and v is not None}


def to_chrome_trace(events) -> dict:
    """Build the Chrome trace-event JSON object (``{"traceEvents": [...]}``)
    from a tracer's event list. Open request residencies (a truncated
    trace) are closed at the last seen tick."""
    out = []
    cell_tids: dict = {}  # (k, m, b) -> tid
    open_res: dict = {}  # rid -> (cell, start_tick, kind)
    span_stack: dict = {}  # name -> [start events]
    last_tick = 0
    out.append(_meta(_PID_CELLS, "serve cells"))
    out.append(_meta(_PID_POOL, "pool"))
    out.append(_meta(_PID_QUEUES, "queues"))
    out.append(_meta(_PID_COMPILE, "compile"))
    out.append(_meta(_PID_SEARCH, "search"))

    def cell_tid(ev):
        key = (ev.get("k", 0), ev.get("m", 0), ev.get("b", 0))
        tid = cell_tids.get(key)
        if tid is None:
            tid = cell_tids[key] = len(cell_tids) + 1
            out.append(_meta(_PID_CELLS,
                             f"cell k{key[0]} m{key[1]} b{key[2]}", tid))
        return tid

    def close_residency(rid, end_tick, how):
        cell, start, kind = open_res.pop(rid)
        tid = cell_tids[cell]
        dur = max(end_tick - start, 1) * TICK_US
        out.append(_slice(_PID_CELLS, tid, start * TICK_US, dur,
                          f"req {rid}", {"rid": rid, "closed_by": how,
                                         "admitted_via": kind}))

    for ev in events:
        name = ev["ev"]
        tick = ev.get("tick", -1)
        if tick is not None and tick >= 0:
            last_tick = max(last_tick, tick)
        ts = max(tick, 0) * TICK_US
        if name in ("admit", "restore"):
            tid = cell_tid(ev)
            rid = ev["rid"]
            if rid in open_res:  # malformed but renderable: close first
                close_residency(rid, tick, "reopen")
            open_res[rid] = ((ev.get("k", 0), ev.get("m", 0),
                              ev.get("b", 0)), max(tick, 0), name)
            out.append(_instant(_PID_CELLS, tid, ts, name, _args(ev)))
        elif name in ("complete", "retract"):
            rid = ev["rid"]
            if rid in open_res:
                tid = cell_tids[open_res[rid][0]]
                out.append(_instant(_PID_CELLS, tid, ts, name, _args(ev)))
                close_residency(rid, max(tick, 0), name)
        elif name == "prefill_chunk":
            out.append(_slice(_PID_CELLS, cell_tid(ev), ts, TICK_US,
                              f"prefill q{ev.get('qlen', '?')}", _args(ev)))
        elif name in _CELL_INSTANTS:
            rid = ev.get("rid")
            if rid in open_res:
                tid = cell_tids[open_res[rid][0]]
            elif any(c in ev for c in ("k", "m", "b")):
                tid = cell_tid(ev)
            else:
                tid = 0
            out.append(_instant(_PID_CELLS, tid, ts, name, _args(ev)))
        elif name == "round":
            if "pool_blocks" in ev:
                out.append(_counter(_PID_POOL, ts, "device blocks in use",
                                    ev["pool_blocks"]))
            for i, depth in enumerate(ev.get("host_depth") or ()):
                out.append(_counter(_PID_POOL, ts, f"host tier p{i}", depth))
            if "inflight" in ev:
                out.append(_counter(_PID_POOL, ts, "transfer in-flight",
                                    ev["inflight"]))
            for i, depth in enumerate(ev.get("queues") or ()):
                out.append(_counter(_PID_QUEUES, ts, f"arch {i} queue",
                                    depth))
            if "occupied" in ev:
                out.append(_counter(_PID_CELLS, ts, "occupied cells",
                                    ev["occupied"]))
        elif name == "enqueue":
            out.append(_instant(_PID_QUEUES, ev.get("arch", 0), ts,
                                f"enqueue {ev['rid']}", _args(ev)))
        elif name in _POOL_INSTANTS:
            out.append(_instant(_PID_POOL, 0, ts, name, _args(ev)))
        elif name == "compile":
            out.append(_instant(_PID_COMPILE, 0,
                                int(ev.get("wall", 0.0) * 1e6),
                                f"compile {ev.get('mode', '?')}", _args(ev)))
        elif name == "span_begin":
            span_stack.setdefault(ev.get("name", "span"), []).append(ev)
        elif name == "span_end":
            stack = span_stack.get(ev.get("name", "span"))
            if stack:
                start = stack.pop()
                ts0 = int(start.get("wall", 0.0) * 1e6)
                dur = max(int(ev.get("wall", 0.0) * 1e6) - ts0, 1)
                label = start.get("name", "span")
                detail = start.get("label") or start.get("arch")
                if detail is not None:
                    label = f"{label} {detail}"
                out.append(_slice(_PID_SEARCH, len(stack), ts0, dur, label,
                                  _args(start, drop=("ev", "tick", "wall"))))
    for rid in sorted(open_res):  # truncated trace: close at last tick
        close_residency(rid, last_tick + 1, "open")
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_perfetto(events, path: str) -> int:
    """Write the Chrome trace-event JSON (Perfetto-loadable) for a tracer's
    events; returns the number of trace records."""
    trace = to_chrome_trace(events)
    with open(path, "w") as f:
        json.dump(trace, f)
        f.write("\n")
    return len(trace["traceEvents"])


__all__ = ["TICK_US", "write_events", "read_events", "write_metrics",
           "to_chrome_trace", "write_perfetto"]
