"""Span-pairing validator for per-request lifecycle traces.

The tracer's per-request taxonomy is a small state machine; this module
checks a recorded event stream actually walked it:

* first event for a rid is ``enqueue``; ``admit`` happens exactly once
  (re-entry after a retraction must be a ``restore``);
* every ``admit`` closes with exactly one ``complete``, or with a
  terminal ``retract`` that is never followed by a ``restore``;
* every ``retract`` carries ``via`` ∈ {swap, recompute, requeue} and a
  ``via="swap"`` retract pairs with a ``swap_out`` for the same rid;
* in-flight events (``prefill_chunk``, ``first_token``, ``spec_*``,
  ``rollback``, ``prefix_hit``, ``swap_out``) only occur while resident;
* per-request engine ticks are monotone non-decreasing.

``validate_spans`` raises :class:`TraceInvariantError` listing every
violation, and returns per-state counts for well-formed traces. It takes
either a live ``Tracer.events`` list or a JSONL reload
(``obs.export.read_events``) — the two are interchangeable.
"""
from __future__ import annotations

# request lifecycle states
_QUEUED, _RUNNING, _RETRACTED, _DONE = "queued", "running", "retracted", "done"

_RESIDENT_ONLY = ("prefill_chunk", "first_token", "prefix_hit", "swap_out",
                  "spec_propose", "spec_verify", "rollback")
_RETRACT_VIAS = ("swap", "recompute", "requeue")


class TraceInvariantError(AssertionError):
    """A trace violated the request-lifecycle state machine."""

    def __init__(self, violations):
        self.violations = list(violations)
        lines = "\n".join(f"  - {v}" for v in self.violations)
        super().__init__(
            f"{len(self.violations)} trace invariant violation(s):\n{lines}")


def validate_spans(events, allow_open: bool = False) -> dict:
    """Check request-lifecycle invariants over an event stream.

    ``allow_open`` accepts a truncated trace (requests still queued or
    resident at the end); a drained engine run must validate with the
    default ``False``.

    Returns ``{"requests", "completed", "retracted_terminal", "violations"}``
    (violations is always 0 on return — otherwise the call raises).
    """
    state: dict = {}  # rid -> lifecycle state
    last_tick: dict = {}  # rid -> last seen tick
    swapped_out: set = set()  # rids with a swap_out since last residency
    completed: set = set()
    bad: list = []

    def expect(rid, ev, *want):
        got = state.get(rid)
        if got not in want:
            bad.append(f"rid {rid}: {ev!r} in state {got!r} "
                       f"(expected {' or '.join(map(repr, want))})")
            return False
        return True

    for i, ev in enumerate(events):
        name = ev.get("ev")
        rid = ev.get("rid")
        if rid is None:
            continue  # round records, compile instants, search spans
        tick = ev.get("tick", -1)
        if tick is not None and tick >= 0:
            prev = last_tick.get(rid)
            if prev is not None and tick < prev:
                bad.append(f"rid {rid}: tick went backwards "
                           f"{prev} -> {tick} at event {i} ({name!r})")
            last_tick[rid] = tick

        if name == "enqueue":
            if rid in state:
                bad.append(f"rid {rid}: duplicate 'enqueue'")
            else:
                state[rid] = _QUEUED
        elif name == "admit":
            if rid not in state:
                bad.append(f"rid {rid}: 'admit' before 'enqueue'")
                state[rid] = _RUNNING
            elif expect(rid, name, _QUEUED):
                state[rid] = _RUNNING
        elif name == "retract":
            via = ev.get("via")
            if via not in _RETRACT_VIAS:
                bad.append(f"rid {rid}: 'retract' via={via!r} (expected one "
                           f"of {_RETRACT_VIAS})")
            if via == "swap" and rid not in swapped_out:
                bad.append(f"rid {rid}: 'retract' via='swap' without a "
                           f"preceding 'swap_out'")
            if expect(rid, name, _RUNNING):
                state[rid] = _RETRACTED
            swapped_out.discard(rid)
        elif name == "restore":
            if expect(rid, name, _RETRACTED):
                state[rid] = _RUNNING
        elif name == "complete":
            if rid in completed:
                bad.append(f"rid {rid}: more than one 'complete'")
            elif expect(rid, name, _RUNNING):
                state[rid] = _DONE
                completed.add(rid)
        elif name in _RESIDENT_ONLY:
            expect(rid, name, _RUNNING)
            if name == "swap_out":
                swapped_out.add(rid)

    if not allow_open:
        for rid, st in sorted(state.items()):
            if st == _RUNNING:
                bad.append(f"rid {rid}: resident at end of trace "
                           f"(no 'complete' or terminal 'retract')")
            elif st == _QUEUED:
                bad.append(f"rid {rid}: still queued at end of trace")

    if bad:
        raise TraceInvariantError(bad)
    return {
        "requests": len(state),
        "completed": len(completed),
        "retracted_terminal": sum(
            1 for st in state.values() if st == _RETRACTED),
        "violations": 0,
    }


__all__ = ["validate_spans", "TraceInvariantError"]
