"""Observability layer: structured tracing, typed metrics, and exporters.

``obs`` gives the serve + search stacks a timeline instead of a single
end-of-run number: per-request lifecycle events, per-round engine
records, a Chrome trace-event/Perfetto export, and a MetricRegistry of
counters/gauges/bounded-reservoir histograms behind ``ServeStats``.
"""
from repro.obs import report  # noqa: F401
from repro.obs.export import (TICK_US, read_events, to_chrome_trace,  # noqa: F401
                              write_events, write_metrics, write_perfetto)
from repro.obs.metrics import (DEFAULT_RESERVOIR_CAP, Counter, Gauge,  # noqa: F401
                               MetricRegistry, Reservoir)
from repro.obs.tracer import NULL_TRACER, NullTracer, Tracer, resolve  # noqa: F401
from repro.obs.validate import TraceInvariantError, validate_spans  # noqa: F401
