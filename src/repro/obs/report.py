"""Human-readable report rendering for the serve driver.

The launch scripts used to interleave ``print`` calls with engine access;
now every report block is a pure function from stats/completions to a
list of lines, and only the launcher (exempt from ruff's T201 wall)
actually prints. Library code stays print-free, and the same lines can be
logged, asserted on, or dropped into a trace without terminal I/O.

Formatting is kept byte-compatible with the historical launcher output —
these lines are the de-facto smoke-test interface people grep.
"""
from __future__ import annotations


def render_capacity_plan(planned, slots: int, paged: bool) -> list:
    line = (f"capacity plan: {planned.n_trials} trial row(s) x "
            f"{planned.n_microbatches} slots fit the HBM budget; "
            f"using {slots} slots/trial")
    if paged:
        line += (f" (pool: {planned.n_blocks} x {planned.block_size}-token "
                 f"blocks per trial")
        if planned.host_blocks:
            line += f" + {planned.host_blocks} host blocks/partition"
        line += ")"
    return [line]


def render_completions(completions, multi_arch: bool = False,
                       limit: int = 8) -> list:
    lines = []
    for c in completions[:limit]:
        arch = f" arch={c.arch}" if multi_arch else ""
        lines.append(f"  req[{c.rid}]{arch} plen={c.prompt_len} "
                     f"queue={c.queue_ticks:.1f} ttft={c.ttft_ticks:.1f} "
                     f"latency={c.latency_ticks:.1f} generated {c.tokens}")
    if len(completions) > limit:
        lines.append(f"  ... {len(completions) - limit} more")
    return lines


def render_summary(mode: str, n_completions: int, s: dict,
                   policy: str = "fcfs") -> list:
    lines = [
        f"{mode}: {n_completions} requests, "
        f"{s['tokens_generated']} tokens generated in {s['ticks']} ticks "
        f"({s['tokens_per_s']} tok/s on this host)",
        f"slot occupancy {s['slot_occupancy']}, "
        f"decode occupancy {s['decode_occupancy']}",
    ]
    if "mixed_calls" in s:
        lines.append(f"fused admission: {s['mixed_calls']} mixed calls out "
                     f"of {s['calls']}, wave fill ratio "
                     f"{s['mixed_fill_ratio']}")
    if "ttft_p50" in s:
        lines.append(
            f"TTFT p50/p95 {s['ttft_p50']}/{s['ttft_p95']} ticks, "
            f"TPOT p50/p95 {s.get('tpot_p50', 0)}/{s.get('tpot_p95', 0)} "
            f"ticks/token [{policy}]")
    if "tokens_per_arch" in s:
        per = ", ".join(f"arch{k}={v}"
                        for k, v in s["tokens_per_arch"].items())
        lines.append(f"tokens per arch: {per}")
    return lines


def render_paged(s: dict, n_blocks: int, block_size: int, host_blocks: int,
                 overcommit: float) -> list:
    lines = [f"block pool: {n_blocks} x {block_size}-token blocks "
             f"per trial, peak in use {s.get('peak_blocks_in_use', 0)}, "
             f"pool stalls {s.get('pool_stalls', 0)}"]
    if overcommit > 1.0 or host_blocks > 0:
        lines.append(f"tiered store: {s.get('retractions', 0)} retractions, "
                     f"{s.get('restored', 0)} restored, "
                     f"{s.get('swap_out_blocks', 0)} blocks swapped out, "
                     f"{s.get('swap_in_blocks', 0)} swapped in "
                     f"(host tier {host_blocks} blocks/partition)")
    return lines


def render_spec(s: dict, sp: dict) -> list:
    ticks_base = s["calls"] / max(s["tokens_generated"], 1)
    ticks_spec = ((s["prefill_calls"] + sp["spec_verify_calls"])
                  / max(s["tokens_generated"], 1))
    return [f"speculation: {sp['spec_accepted']}/{sp['spec_proposed']} "
            f"drafts accepted (rate {sp['acceptance_rate']}), "
            f"{sp['spec_bonus_tokens']} bonus tokens, "
            f"{sp['spec_draft_calls']} draft calls / "
            f"{sp['spec_verify_calls']} verify calls, "
            f"{sp['spec_rollback_blocks']} blocks rolled back; "
            f"target ticks/token {ticks_spec:.3f} "
            f"(vs {ticks_base:.3f} counting drafter ticks)"]


def render_prefix(s: dict) -> list:
    return [f"prefix cache: {s.get('prefix_hits', 0)} hits "
            f"({s.get('prefix_hit_tokens', 0)} tokens, "
            f"{s.get('host_hit_tokens', 0)} via host restores), "
            f"{s.get('prefix_inserts', 0)} blocks cached, "
            f"{s.get('prefix_spills', 0)} spilled to host, "
            f"{s.get('prefix_evictions', 0)} evicted, "
            f"{s.get('cow_forks', 0)} CoW forks"]


__all__ = ["render_capacity_plan", "render_completions", "render_summary",
           "render_paged", "render_spec", "render_prefix"]
