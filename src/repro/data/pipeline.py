"""Data pipeline: deterministic synthetic token streams, host-sharded loading
and background prefetch.

Real deployments swap ``SyntheticTokenSource`` for a tokenized corpus reader;
everything downstream (host sharding, slot-major batch layout, prefetch)
is production-shaped. Determinism contract: the tokens for (trial k, step t,
microbatch m, row r) depend only on (seed, k, t, m, r) — so a restarted or
re-sharded job sees identical data, which keeps Hydra's exact-replication
guarantee (paper D3) across failures and elastic re-meshes.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.pipeline import EngineConfig


def _philox(seed: int, *counters: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(key=seed, counter=counters[0]))


@dataclasses.dataclass(frozen=True)
class SyntheticTokenSource:
    """Zipf-ish synthetic token stream (deterministic per coordinates)."""

    vocab_size: int
    seq_len: int
    seed: int = 0

    def sequence(self, trial: int, step: int, micro: int, row: int) -> np.ndarray:
        ctr = ((trial * 1_000_003 + step) * 1_000_033 + micro) * 1_000_037 + row
        rng = _philox(self.seed, ctr)
        # zipf-flavored ids clipped to vocab (more realistic than uniform)
        raw = rng.zipf(1.3, size=self.seq_len + 1)
        return (raw % self.vocab_size).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class HostShard:
    """Which global batch rows this host materializes (multi-host loading)."""

    process_index: int
    process_count: int

    def rows(self, global_rows: int) -> range:
        per = global_rows // self.process_count
        lo = self.process_index * per
        hi = global_rows if self.process_index == self.process_count - 1 \
            else lo + per
        return range(lo, hi)


def _gen_tokens(vocab: int, seq: int, eng: EngineConfig, step: int,
                seed: int) -> np.ndarray:
    mb_global = eng.microbatch * (1 if eng.batch_replicated
                                  else eng.data_size * eng.pod_size)
    src = SyntheticTokenSource(vocab, seq, seed)
    out = np.empty((eng.n_trials, eng.n_microbatches, mb_global, seq + 1),
                   np.int32)
    for k in range(eng.n_trials):
        for m in range(eng.n_microbatches):
            for r in range(mb_global):
                out[k, m, r] = src.sequence(k, step, m, r)
    return out


class TrainBatches:
    """Iterator of slot-major train batches with background prefetch."""

    def __init__(self, cfg: ArchConfig, eng: EngineConfig, seq_len: int,
                 seed: int = 0, prefetch: int = 2,
                 frontend_fn=None, mrope_fn=None):
        self.cfg, self.eng, self.seq_len, self.seed = cfg, eng, seq_len, seed
        self.frontend_fn, self.mrope_fn = frontend_fn, mrope_fn
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = 0
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def batch_for_step(self, step: int) -> dict:
        full = _gen_tokens(self.cfg.vocab_size, self.seq_len, self.eng, step,
                           self.seed)
        batch = {"tokens": full[..., :-1], "labels": full[..., 1:]}
        if self.cfg.frontend is not None:
            nf = self.cfg.n_frontend_tokens
            mbg = full.shape[2]
            rng = _philox(self.seed + 17, step)
            batch["frontend_embeds"] = rng.standard_normal(
                (self.eng.n_trials, self.eng.n_microbatches, mbg, nf,
                 self.cfg.d_model)).astype(np.float32)
        if self.cfg.rope == "mrope":
            mbg = full.shape[2]
            batch["mrope_pos"] = np.broadcast_to(
                np.arange(self.seq_len, dtype=np.int32),
                (self.eng.n_trials, self.eng.n_microbatches, 3, mbg,
                 self.seq_len)).copy()
        return batch

    def _producer(self):
        while not self._stop.is_set():
            b = self.batch_for_step(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self._q.put(b, timeout=0.25)
                    break
                except queue.Full:
                    continue

    def __next__(self) -> dict:
        return self._q.get()

    def __iter__(self) -> Iterator[dict]:
        return self

    def close(self):
        self._stop.set()
