from repro.data.pipeline import SyntheticTokenSource, TrainBatches  # noqa: F401
