"""Continuous-batching serve subsystem (request queue → pipeline slots)."""
from repro.serve.request import (  # noqa: F401
    Completion,
    Request,
    load_trace,
    poisson_trace,
    save_trace,
)
from repro.serve.batcher import Batcher, Slot  # noqa: F401
from repro.serve.engine import ServeEngine, static_serve  # noqa: F401
from repro.serve.paging import (  # noqa: F401
    BlockAllocator,
    BlockTable,
    blocks_for,
)
