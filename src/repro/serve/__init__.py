"""Continuous-batching serve subsystem: per-arch request queues routed onto
the (trial k, microbatch m, batch-row b) slot grid of one co-serving gang."""
from repro.serve.request import (  # noqa: F401
    Completion,
    Request,
    load_trace,
    poisson_trace,
    save_trace,
)
from repro.serve.batcher import (  # noqa: F401
    POLICIES,
    Batcher,
    ResumeState,
    Slot,
)
from repro.serve.engine import (  # noqa: F401
    ServeEngine,
    ServeStats,
    SpecStats,
    static_serve,
)
from repro.serve.paging import (  # noqa: F401
    BlockAllocator,
    BlockTable,
    blocks_for,
)
from repro.serve.prefix_cache import PrefixCache, PrefixHit  # noqa: F401
from repro.serve.store import BlockStore, HostBlock  # noqa: F401
from repro.serve.transfer import TransferEngine, make_null_transfer  # noqa: F401
