"""Admission scheduling: map per-arch request queues onto the (k, m, b) grid.

The pipelined serve step has a fixed slot grid — ``n_trials`` trial rows ×
``n_microbatches`` microbatch slots × ``mb_global`` batch rows — and every
(k, m, b) cell owns one KV/SSM-cache row of trial k. Trial row k holds the
weights of model variant k (the co-serving analogue of the paper's gang: K
model variants sharded onto one device gang), so a request addressed to
``arch`` a may only ever occupy cells with k == a.

The :class:`Batcher` keeps one queue per arch, admits each queue into its own
trial rows under the configured ``policy`` (FCFS / shortest-prompt-first /
deadline-aware — ordering is always *within* an arch; arches never compete
for each other's cells), and plans chunked prefill *waves*: each admitted
prompt is split into ``prefill_chunks`` near-equal chunks, and each wave
groups cells by next-chunk length so every pipeline call keeps a static token
shape (cells in the same call may sit at different cache depths — the append
step takes per-row kv offsets).

Paged backpressure is per (trial, data-shard) pool partition: an arch whose
head request cannot commit its blocks defers *only that arch's* admission —
other arches keep admitting into their own partitions, so one overloaded
variant can never starve the rest of the gang (the cross-arch guard the
engine's stall detector backstops).

With a radix ``prefix_cache`` (paged only), admission additionally matches
each head request's prompt against every candidate partition's tree and
commits only the *non-cached* block need: the slot is seeded with the shared
prefix blocks, ``Slot.pos`` starts at the hit boundary, and the committed
total counts each referenced cached block once across the partition's live
slots (shared residency is charged exactly once; unreferenced cached blocks
are evictable and never charged). Host-resident (spilled) matched nodes are
charged one fresh block each — their restore allocates from the pool.

Gang speculation (``spec_pairs``): target trial row k is paired with drafter
row ``spec_pairs[k]``. Admitting a request to a target cell (k, m, b) also
claims the *mirror* drafter cell (spec_pairs[k], m, b) — same request, own
block table in the drafter row's partition — so a request is only admitted
when BOTH its target commitment and its drafter commitment fit
(:meth:`_attach_draft`). Drafter cells never admit requests of their own
(their rows are reserved), never prefill (their cache is built by catch-up
appends from the committed stream), and are excluded from
:meth:`decode_slots` (the engine drives them through its draft calls). The
pair lives and dies atomically: completion and retraction release both cells.

Retraction (overcommit > 1): the engine may :meth:`Batcher.requeue` a
running request it preempted under pool exhaustion, together with a
:class:`ResumeState` continuation. The request re-enters the *head* of its
arch's queue (it was admitted once — oldest priority, and victim selection
is youngest-first, so a restored request is not immediately re-victimized)
and admission places it down one of two bit-identical paths: swap-restore
(``host_ids`` set — fresh device blocks + async swap-in of the extracted
payloads, straight back to decode) or recompute-restore (replay
prompt ++ generated tokens as a teacher-forced prefill).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.obs.tracer import resolve
from repro.serve.paging import BlockAllocator, BlockTable, blocks_for
from repro.serve.request import Request

POLICIES = ("fcfs", "sjf", "deadline")


@dataclasses.dataclass
class Slot:
    """One (trial k, microbatch m, batch-row b) cell of the serve grid."""

    k: int
    m: int
    b: int
    request: Optional[Request] = None
    pos: int = 0  # tokens currently written to this cell's cache row
    chunks: list = dataclasses.field(default_factory=list)  # pending prompt
    generated: list = dataclasses.field(default_factory=list)
    admitted_tick: int = -1
    first_token_tick: int = -1  # tick the head emitted this request's first token
    table: Optional[BlockTable] = None  # paged: this request's block table
    block_commit: int = 0  # paged: exact NEW blocks this request will peak at
    cached_ids: set = dataclasses.field(default_factory=set)  # prefix-hit
    # blocks this slot references (shared; charged once per partition)
    hit_tokens: int = 0  # prefix-cache hit length (prefill starts here)
    resumed: bool = False  # restored from a retraction (stats count once)
    resume_tokens: Optional[list] = None  # recompute-restore: the tokens
    # generated before retraction; the teacher-forced replay re-derives them
    # (asserted bit-identical) instead of re-sampling
    is_draft: bool = False  # gang speculation: this cell drafts for ``peer``
    peer: Optional["Slot"] = None  # paired drafter/target mirror cell

    @property
    def free(self) -> bool:
        return self.request is None

    @property
    def prefilling(self) -> bool:
        return self.request is not None and bool(self.chunks)

    @property
    def decoding(self) -> bool:
        return self.request is not None and not self.chunks

    @property
    def finished(self) -> bool:
        return (self.request is not None and not self.chunks
                and len(self.generated) >= self.request.max_new_tokens)

    def release(self) -> None:
        self.request = None
        self.pos = 0
        self.chunks = []
        self.generated = []
        self.admitted_tick = -1
        self.first_token_tick = -1
        if self.table is not None:  # drop references on completion
            self.table.close()
            self.table = None
        self.block_commit = 0
        self.cached_ids = set()
        self.hit_tokens = 0
        self.resumed = False
        self.resume_tokens = None
        self.is_draft = False
        self.peer = None


@dataclasses.dataclass
class ResumeState:
    """Continuation of a retracted (preempted) request, held while it waits
    in the queue for re-admission. ``host_ids`` set = swap-restore (the
    victim's table payloads sit pinned in the host tier of ``partition``);
    None = recompute-restore (replay prompt ++ generated[:-1] as a
    teacher-forced prefill — the replay's final head output must re-derive
    ``generated[-1]``)."""

    generated: list  # tokens emitted before retraction (>= 1)
    pos: int  # cache depth at retraction (prompt_len + len(generated) - 1)
    admitted_tick: int  # original admission (victim ordering + queue stats)
    first_token_tick: int  # original TTFT tick (latency stats stay honest)
    partition: int = -1  # host-tier partition holding the swapped payloads
    host_ids: Optional[list] = None  # pinned host blocks, table order


class Batcher:
    """Per-arch admission of queued requests into the arch's trial rows.

    ``n_trials`` is the gang width K: request ``arch`` a is only ever placed
    in cells (a, m, b). ``policy`` orders admission *within* an arch's queue
    among the requests that have arrived:

    * ``"fcfs"``   — arrival order (the default);
    * ``"sjf"``    — shortest prompt first (minimizes mean TTFT under load);
    * ``"deadline"`` — earliest ``Request.deadline`` first (None sorts last).

    With a :class:`BlockAllocator` (paged serving), the pool is split into one
    partition per (trial, data-shard) pair — partition k * n_shards + shard —
    so each trial row's cache writes land in its own pool slice and admission
    additionally commits each request's exact block footprint (generation
    always runs to its budget, so ``blocks_for(total_len)`` is known at
    admission) against its partition, deferring — per-arch backpressure —
    when the committed total would exceed ``blocks_per_partition ×
    overcommit``. At the default overcommit of 1.0 the schedule is
    preemption-free: every later alloc-on-append is covered by its
    commitment and can never stall. ``rows_per_partition`` maps batch row b
    to data shard b // rows_per_partition.
    """

    def __init__(self, n_microbatches: int, mb_global: int,
                 prefill_chunks: int, max_seq: int,
                 n_trials: int = 1,
                 allocator: Optional[BlockAllocator] = None,
                 rows_per_partition: int = 0, overcommit: float = 1.0,
                 policy: str = "fcfs", prefix_cache=None, store=None,
                 transfer=None, spec_pairs=None, tracer=None):
        if policy not in POLICIES:
            raise ValueError(f"unknown admission policy {policy!r} "
                             f"(choose from {POLICIES})")
        if prefix_cache is not None and allocator is None:
            raise ValueError("prefix_cache requires a paged BlockAllocator")
        self.n_trials = n_trials
        self.spec_pairs = dict(spec_pairs or {})  # target row -> drafter row
        self.draft_rows = set(self.spec_pairs.values())
        self.prefix_cache = prefix_cache
        # the tiered store routes allocation-pressure reclamation; a cache
        # always carries one (legacy wiring), otherwise it may be passed
        self.store = store if store is not None else (
            prefix_cache.store if prefix_cache is not None else None)
        self.transfer = transfer  # TransferEngine (swap-restore admission)
        self.trace = resolve(tracer)
        self.resume: dict = {}  # rid -> ResumeState for retracted requests
        self.restored = 0  # retracted requests brought back into a slot
        self.n_microbatches = n_microbatches
        self.mb_global = mb_global
        self.prefill_chunks = max(1, prefill_chunks)
        self.max_seq = max_seq
        self.allocator = allocator
        self.rows_per_partition = rows_per_partition
        self.overcommit = overcommit
        self.policy = policy
        self.slots = [Slot(k, m, b) for k in range(n_trials)
                      for m in range(n_microbatches)
                      for b in range(mb_global)]
        self.queues: list[list] = [[] for _ in range(n_trials)]

    @property
    def n_shards(self) -> int:
        """Data-shard partitions per trial (1 when unsharded/unpaged)."""
        if self.allocator is None:
            return 1
        return self.allocator.n_partitions // self.n_trials

    def partition_of(self, k: int, b: int) -> int:
        if self.allocator is None:
            return 0
        shard = 0
        if self.rows_per_partition > 0:
            shard = min(b // self.rows_per_partition, self.n_shards - 1)
        return k * self.n_shards + shard

    def committed_blocks(self, partition: int) -> int:
        """Blocks promised to live requests in one pool partition: each
        slot's exact new-block commitment, plus every *referenced* cached
        block counted once — shared prefix blocks are pinned (unevictable)
        while a live slot reads them, so they charge the partition exactly
        once no matter how many slots share them."""
        total, referenced = 0, set()
        for s in self.slots:
            if s.free or self.partition_of(s.k, s.b) != partition:
                continue
            total += s.block_commit
            referenced |= s.cached_ids
        return total + len(referenced)

    def _referenced_cached(self, partition: int) -> set:
        out = set()
        for s in self.slots:
            if not s.free and self.partition_of(s.k, s.b) == partition:
                out |= s.cached_ids
        return out

    # -- queue ---------------------------------------------------------------

    def cell(self, k: int, m: int, b: int) -> Slot:
        """The Slot at grid coordinate (k, m, b)."""
        return self.slots[(k * self.n_microbatches + m) * self.mb_global + b]

    def enqueue(self, req: Request) -> None:
        if req.arch >= self.n_trials:
            raise ValueError(
                f"request {req.rid}: arch={req.arch} but this gang co-serves "
                f"{self.n_trials} variant(s) (trial rows 0..{self.n_trials - 1})")
        if req.arch in self.draft_rows:
            raise ValueError(
                f"request {req.rid}: arch={req.arch} is a drafter row "
                f"(reserved for gang speculation); address a target row")
        if req.total_len > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt_len + max_new_tokens - 1 = "
                f"{req.total_len} exceeds the engine cache length "
                f"{self.max_seq}")
        if self.allocator is not None:
            need = blocks_for(req.total_len, self.allocator.block_size)
            # a request can never be admitted past the physical partition
            # size OR past the admission limit (overcommit < 1 lowers it)
            ceiling = min(self.allocator.blocks_per_partition,
                          int(self.allocator.blocks_per_partition
                              * self.overcommit))
            if need > ceiling:
                raise ValueError(
                    f"request {req.rid}: needs {need} blocks but admission "
                    f"is capped at {ceiling} per pool partition "
                    f"(blocks_per_partition="
                    f"{self.allocator.blocks_per_partition}, overcommit="
                    f"{self.overcommit}) — it could never be admitted")
        if self.trace.enabled:
            self.trace.req("enqueue", req.rid, arch=req.arch,
                           plen=req.prompt_len)
        self.queues[req.arch].append(req)

    def requeue(self, req: Request,
                state: Optional["ResumeState"] = None) -> None:
        """Put a retracted request back at the *head* of its arch's queue —
        it was admitted once, so it outranks everything still waiting — with
        its continuation (None = retracted mid-prefill, plain re-admission
        from scratch)."""
        self.queues[req.arch].insert(0, req)
        if state is not None:
            self.resume[req.rid] = state

    # -- admission -----------------------------------------------------------

    def split_chunks(self, prompt: np.ndarray, full_len: int = 0) -> list:
        """Near-equal prompt chunks (lengths differ by at most 1), so a trace
        with L distinct prompt lengths compiles at most 2L append shapes.

        ``full_len`` > len(prompt) marks a prefix-cache hit: ``prompt`` is
        the uncached suffix of a ``full_len``-token prompt, and the chunk
        count shrinks proportionally (the suffix is split at the chunk size
        the *full* prompt would have used) — a hit saves whole prefill
        waves, not just tokens per wave."""
        n = int(prompt.shape[0])
        if full_len > n:
            per_chunk = -(-full_len // self.prefill_chunks)
            nc = min(max(1, -(-n // per_chunk)), n)
        else:
            nc = min(self.prefill_chunks, n)
        return [c for c in np.array_split(prompt, nc) if c.size]

    def _head(self, k: int, now: float) -> Optional[Request]:
        """The next admissible request of arch k under the policy (among the
        requests that have arrived), without removing it from the queue."""
        arrived = [r for r in self.queues[k] if r.arrival <= now]
        if not arrived:
            return None
        if self.policy == "sjf":
            return min(arrived, key=lambda r: (r.prompt_len, r.arrival, r.rid))
        if self.policy == "deadline":
            inf = float("inf")
            return min(arrived, key=lambda r: (
                r.deadline if r.deadline is not None else inf,
                r.arrival, r.rid))
        return arrived[0]  # fcfs: queues preserve arrival order

    def admit(self, now: float) -> list:
        """Move queued requests (arrival <= now) into free cells of their own
        arch's trial rows, ordered per the admission policy within each arch.

        Paged: each arch's head request is placed in the free cell whose pool
        partition has the fewest committed blocks, and *that arch's*
        admission stops (defers — the queue keeps its order) as soon as the
        head's exact block commitment fits none of the arch's partitions.
        Other arches continue admitting into their own partitions, so pool
        exhaustion in one variant never starves the rest of the gang.

        With a prefix cache, each candidate partition is first matched
        against the head's prompt; cells are tried longest-hit-first (then
        fewest-committed) and the admitted slot commits only its non-cached
        block need, seeded with the shared prefix blocks at ``pos`` =
        hit length. Returns the newly admitted slots.
        """
        admitted = []
        for k in range(self.n_trials):
            if k in self.draft_rows:
                continue  # reserved: drafter cells fill via _attach_draft
            free = [s for s in self.slots if s.free and s.k == k]
            if k in self.spec_pairs:
                # pairing admission: a target cell is only usable when its
                # mirror drafter cell is free too (pairs release atomically,
                # so this is belt-and-braces)
                kd = self.spec_pairs[k]
                free = [s for s in free if self.cell(kd, s.m, s.b).free]
            while free:
                req = self._head(k, now)
                if req is None:
                    break
                state = self.resume.get(req.rid)
                if state is not None and state.host_ids is not None:
                    # swap-restore: fresh blocks + async swap-in, no prefill
                    slot = self._place_restore(req, state, free)
                    if slot is None:
                        break
                    free.remove(slot)
                    self.queues[k].remove(req)
                    del self.resume[req.rid]
                    self.restored += 1
                    self._attach_draft(slot)
                    admitted.append(slot)
                    continue
                # recompute-restore rides the normal placement with the
                # teacher-forced replay prompt (prefix hits may re-seed it)
                replay = None
                if state is not None:
                    replay = np.concatenate(
                        [req.prompt, np.asarray(state.generated[:-1],
                                                req.prompt.dtype)])
                if self.allocator is None:
                    slot = free.pop(0)
                else:
                    slot = self._place_paged(req, free, prompt=replay)
                    if slot is None:  # per-arch pool backpressure: defer
                        break
                    free.remove(slot)
                self.queues[k].remove(req)
                slot.request = req
                slot.pos = slot.hit_tokens
                src = req.prompt if replay is None else replay
                slot.chunks = self.split_chunks(src[slot.pos:],
                                                full_len=int(src.shape[0]))
                slot.generated = []
                slot.admitted_tick = int(now)
                if state is not None:
                    del self.resume[req.rid]
                    self.restored += 1
                    slot.resumed = True
                    slot.resume_tokens = list(state.generated)
                    slot.admitted_tick = state.admitted_tick
                    slot.first_token_tick = state.first_token_tick
                self._attach_draft(slot)
                admitted.append(slot)
        return admitted

    def _attach_draft(self, slot: Slot) -> None:
        """Claim the mirror drafter cell for a freshly placed target slot
        (gang speculation). The drafter shares the target's Request but owns
        its own block table in the drafter row's partition; it gets no
        prompt chunks — its cache is rebuilt by the engine's catch-up
        appends from the committed stream, starting at position 0 after any
        admission (including restores, where the target resumes mid-decode).
        Capacity for ``Request.draft_total_len`` was already checked by the
        placement path."""
        kd = self.spec_pairs.get(slot.k)
        if kd is None:
            return
        d = self.cell(kd, slot.m, slot.b)
        assert d.free, "drafter mirror cell occupied"
        req = slot.request
        d.request = req
        d.is_draft = True
        d.peer = slot
        slot.peer = d
        d.pos = 0
        d.chunks = []
        d.generated = []
        d.admitted_tick = slot.admitted_tick
        if self.allocator is not None:
            p = self.partition_of(kd, d.b)
            d.table = BlockTable(self.allocator, p, store=self.store)
            d.block_commit = blocks_for(req.draft_total_len,
                                        self.allocator.block_size)

    def _place_paged(self, req: Request, free: list,
                     prompt=None) -> Optional[Slot]:
        """Pick and prepare a paged slot for ``req``: match the prefix cache
        per candidate partition, charge the non-cached commitment, seed the
        table. ``prompt`` overrides the matched/prefilled token stream (the
        recompute-restore replay). None = no partition fits (defer)."""
        prompt = req.prompt if prompt is None else prompt
        bs = self.allocator.block_size
        total_need = blocks_for(req.total_len, bs)
        limit = int(self.allocator.blocks_per_partition * self.overcommit)
        # gang speculation: admission also reserves the mirror drafter
        # cell's commitment in the drafter row's partition
        kd = self.spec_pairs.get(req.arch)
        draft_need = (blocks_for(req.draft_total_len, bs)
                      if kd is not None else 0)
        # per-partition state once per placement (candidate slots map onto
        # only K*n_shards partitions — don't rescan the grid per candidate)
        tparts = {self.partition_of(c.k, c.b) for c in free}
        parts = set(tparts)
        if kd is not None:
            parts |= {self.partition_of(kd, c.b) for c in free}
        committed, hits, pinned = {}, {}, {}
        for p in parts:
            committed[p] = self.committed_blocks(p)
        for p in tparts:
            if self.prefix_cache is not None:
                hits[p] = self.prefix_cache.match(p, prompt)
                pinned[p] = self._referenced_cached(p)

        def hit_len(p):
            return hits[p].hit_tokens if p in hits else 0

        def fits(c):
            # commitment = new blocks + cached blocks this request would pin
            # that no live slot pins yet (pinned blocks charge once) + one
            # fresh block per host-resident matched node (its restore
            # allocates from the pool); committed_blocks() already balances
            # by *committed* blocks, not the allocator's free count —
            # commitments from requests admitted earlier this round have not
            # allocated yet but already claim their pool
            p = self.partition_of(c.k, c.b)
            commit = total_need
            fresh_refs = 0
            if p in hits:
                commit -= hits[p].n_full_blocks
                fresh_refs = (sum(1 for b in hits[p].device_ids
                                  if b not in pinned[p])
                              + hits[p].n_host_blocks)
            if committed[p] + commit + fresh_refs > limit:
                return False
            if kd is not None:  # the drafter commitment must fit too
                pd = self.partition_of(kd, c.b)
                if committed[pd] + draft_need > limit:
                    return False
            return True

        # longest hit first (prefix reuse beats perfect balance), then the
        # partition with the fewest committed blocks
        ordered = sorted(free, key=lambda s: (
            -hit_len(self.partition_of(s.k, s.b)),
            committed[self.partition_of(s.k, s.b)], s.m, s.b))
        slot = next((c for c in ordered if fits(c)), None)
        if slot is None:
            return None
        p = self.partition_of(slot.k, slot.b)
        slot.table = BlockTable(self.allocator, p, store=self.store)
        slot.block_commit = total_need
        slot.cached_ids = set()
        slot.hit_tokens = 0
        if p in hits and hits[p].hit_tokens > 0:
            # acquire restores host-resident matched nodes (async swap-in)
            # and returns the *effective* hit — possibly truncated when the
            # pool cannot back a restore under overcommit races
            hit = self.prefix_cache.acquire(hits[p])
            if hit.hit_tokens > 0:
                slot.table.seed(hit.block_ids)
                slot.block_commit = total_need - hit.n_full_blocks
                slot.cached_ids = set(hit.block_ids)
                slot.hit_tokens = hit.hit_tokens
        return slot

    def _place_restore(self, req: Request, state: ResumeState,
                       free: list) -> Optional[Slot]:
        """Swap-restore placement: allocate fresh device blocks for the
        retracted request's extracted payloads and enqueue their swap-in —
        the slot resumes *decoding* at its retracted position once the
        round's transfer flush lands the bytes (no prefill replay).
        None = no partition can back it yet (defer; the pinned host blocks
        wait)."""
        bs = self.allocator.block_size
        total_need = blocks_for(req.total_len, bs)
        limit = int(self.allocator.blocks_per_partition * self.overcommit)
        kd = self.spec_pairs.get(req.arch)
        draft_need = (blocks_for(req.draft_total_len, bs)
                      if kd is not None else 0)
        parts = {self.partition_of(c.k, c.b) for c in free}
        if kd is not None:
            parts |= {self.partition_of(kd, c.b) for c in free}
        committed = {p: self.committed_blocks(p) for p in parts}
        ordered = sorted(free, key=lambda s: (
            committed[self.partition_of(s.k, s.b)], s.m, s.b))
        n = len(state.host_ids)
        for cand in ordered:
            p = self.partition_of(cand.k, cand.b)
            if committed[p] + total_need > limit:
                continue
            if kd is not None and (committed[self.partition_of(kd, cand.b)]
                                   + draft_need > limit):
                continue
            table = BlockTable(self.allocator, p, store=self.store)
            if not table.ensure(n * bs):  # physical pressure: next partition
                continue
            for dst, hid in zip(table.blocks, state.host_ids):
                self.transfer.swap_in(
                    p, dst, self.store.host_pop(state.partition, hid))
            cand.table = table
            cand.request = req
            cand.pos = state.pos
            cand.chunks = []
            cand.generated = list(state.generated)
            cand.admitted_tick = state.admitted_tick
            cand.first_token_tick = state.first_token_tick
            cand.block_commit = total_need
            cand.cached_ids = set()
            cand.hit_tokens = 0
            cand.resumed = True
            return cand
        return None

    # -- wave planning -------------------------------------------------------

    def prefill_groups(self) -> dict:
        """{chunk_len: [slots]} for the cells whose next prompt chunk has
        that length — one static-shape append call per key (slots of every
        trial row ride in the same call; the step carries a k index per cell)."""
        groups: dict = {}
        for s in self.slots:
            if s.prefilling:
                groups.setdefault(int(s.chunks[0].shape[0]), []).append(s)
        return groups

    def decode_slots(self) -> list:
        """Decoding cells, drafters excluded — the engine drives drafter
        cells itself inside its speculative draft/verify rounds."""
        return [s for s in self.slots
                if s.decoding and not s.finished and not s.is_draft]

    def occupied(self) -> int:
        return sum(1 for s in self.slots if not s.free)

    @property
    def n_cells(self) -> int:
        return len(self.slots)

    def queued(self) -> int:
        return sum(len(q) for q in self.queues)

    def idle(self) -> bool:
        return self.queued() == 0 and all(s.free for s in self.slots)
