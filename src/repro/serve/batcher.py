"""Admission scheduling: map a dynamic request queue onto pipeline slots.

The pipelined serve step has a fixed slot grid — ``n_microbatches``
microbatch slots × ``mb_global`` batch rows per slot — and every (m, b) cell
owns one KV/SSM-cache row. The :class:`Batcher` tracks which cell holds which
request, admits queued requests FCFS into freed cells, and plans chunked
prefill *waves*: each admitted prompt is split into ``prefill_chunks``
near-equal chunks, and each wave groups cells by next-chunk length so every
pipeline call keeps a static token shape (cells in the same call may sit at
different cache depths — the append step takes per-row kv offsets).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.serve.paging import BlockAllocator, BlockTable, blocks_for
from repro.serve.request import Request


@dataclasses.dataclass
class Slot:
    """One (microbatch m, batch-row b) cell of the serve grid."""

    m: int
    b: int
    request: Optional[Request] = None
    pos: int = 0  # tokens currently written to this cell's cache row
    chunks: list = dataclasses.field(default_factory=list)  # pending prompt
    generated: list = dataclasses.field(default_factory=list)
    admitted_tick: int = -1
    table: Optional[BlockTable] = None  # paged: this request's block table
    block_commit: int = 0  # paged: exact blocks this request will peak at

    @property
    def free(self) -> bool:
        return self.request is None

    @property
    def prefilling(self) -> bool:
        return self.request is not None and bool(self.chunks)

    @property
    def decoding(self) -> bool:
        return self.request is not None and not self.chunks

    @property
    def finished(self) -> bool:
        return (self.request is not None and not self.chunks
                and len(self.generated) >= self.request.max_new_tokens)

    def release(self) -> None:
        self.request = None
        self.pos = 0
        self.chunks = []
        self.generated = []
        self.admitted_tick = -1
        if self.table is not None:  # free-on-completion
            self.table.close()
            self.table = None
        self.block_commit = 0


class Batcher:
    """FCFS admission of queued requests into free slot cells.

    With a :class:`BlockAllocator` (paged serving), admission additionally
    commits each request's exact block footprint (generation always runs to
    its budget, so ``blocks_for(total_len)`` is known at admission) against
    its pool partition and defers — backpressure — when the committed total
    would exceed ``blocks_per_partition × overcommit``. At the default
    overcommit of 1.0 the schedule is preemption-free: every later
    alloc-on-append is covered by its commitment and can never stall.
    ``rows_per_partition`` maps batch row b to pool partition
    b // rows_per_partition (the data/pod shard holding that row).
    """

    def __init__(self, n_microbatches: int, mb_global: int,
                 prefill_chunks: int, max_seq: int,
                 allocator: Optional[BlockAllocator] = None,
                 rows_per_partition: int = 0, overcommit: float = 1.0):
        self.n_microbatches = n_microbatches
        self.mb_global = mb_global
        self.prefill_chunks = max(1, prefill_chunks)
        self.max_seq = max_seq
        self.allocator = allocator
        self.rows_per_partition = rows_per_partition
        self.overcommit = overcommit
        self.slots = [Slot(m, b) for m in range(n_microbatches)
                      for b in range(mb_global)]
        self.queue: deque = deque()

    def partition_of(self, b: int) -> int:
        if self.allocator is None or self.rows_per_partition <= 0:
            return 0
        return min(b // self.rows_per_partition,
                   self.allocator.n_partitions - 1)

    def committed_blocks(self, partition: int) -> int:
        """Blocks promised to live requests in one pool partition."""
        return sum(s.block_commit for s in self.slots
                   if not s.free and self.partition_of(s.b) == partition)

    # -- queue ---------------------------------------------------------------

    def enqueue(self, req: Request) -> None:
        if req.total_len > self.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt_len + max_new_tokens - 1 = "
                f"{req.total_len} exceeds the engine cache length "
                f"{self.max_seq}")
        if self.allocator is not None:
            need = blocks_for(req.total_len, self.allocator.block_size)
            # a request can never be admitted past the physical partition
            # size OR past the admission limit (overcommit < 1 lowers it)
            ceiling = min(self.allocator.blocks_per_partition,
                          int(self.allocator.blocks_per_partition
                              * self.overcommit))
            if need > ceiling:
                raise ValueError(
                    f"request {req.rid}: needs {need} blocks but admission "
                    f"is capped at {ceiling} per pool partition "
                    f"(blocks_per_partition="
                    f"{self.allocator.blocks_per_partition}, overcommit="
                    f"{self.overcommit}) — it could never be admitted")
        self.queue.append(req)

    # -- admission -----------------------------------------------------------

    def split_chunks(self, prompt: np.ndarray) -> list:
        """Near-equal prompt chunks (lengths differ by at most 1), so a trace
        with L distinct prompt lengths compiles at most 2L append shapes."""
        nc = min(self.prefill_chunks, prompt.shape[0])
        return [c for c in np.array_split(prompt, nc) if c.size]

    def admit(self, now: float) -> list:
        """Move queued requests (arrival <= now) into free cells, FCFS.

        Paged: the head request is placed in the free cell whose pool
        partition has the most free blocks, and admission stops (defers —
        the queue keeps FCFS order) as soon as the head's exact block
        commitment fits no partition. Returns the newly admitted slots.
        """
        admitted = []
        free = [s for s in self.slots if s.free]
        while free and self.queue and self.queue[0].arrival <= now:
            req = self.queue[0]
            if self.allocator is None:
                slot = free.pop(0)
            else:
                commit = blocks_for(req.total_len, self.allocator.block_size)
                limit = int(self.allocator.blocks_per_partition
                            * self.overcommit)
                # balance by *committed* blocks, not the allocator's free
                # count — commitments from requests admitted earlier this
                # round have not allocated yet but already claim their pool
                free.sort(key=lambda s: (
                    self.committed_blocks(self.partition_of(s.b)),
                    s.m, s.b))
                slot = None
                for cand in free:
                    p = self.partition_of(cand.b)
                    if self.committed_blocks(p) + commit <= limit:
                        slot = cand
                        break
                if slot is None:  # pool backpressure: defer admission
                    break
                free.remove(slot)
                slot.table = BlockTable(self.allocator,
                                        self.partition_of(slot.b))
                slot.block_commit = commit
            self.queue.popleft()
            slot.request = req
            slot.pos = 0
            slot.chunks = self.split_chunks(req.prompt)
            slot.generated = []
            slot.admitted_tick = int(now)
            admitted.append(slot)
        return admitted

    # -- wave planning -------------------------------------------------------

    def prefill_groups(self) -> dict:
        """{chunk_len: [slots]} for the cells whose next prompt chunk has
        that length — one static-shape append call per key."""
        groups: dict = {}
        for s in self.slots:
            if s.prefilling:
                groups.setdefault(int(s.chunks[0].shape[0]), []).append(s)
        return groups

    def decode_slots(self) -> list:
        return [s for s in self.slots if s.decoding and not s.finished]

    def occupied(self) -> int:
        return sum(1 for s in self.slots if not s.free)

    @property
    def n_cells(self) -> int:
        return len(self.slots)

    def idle(self) -> bool:
        return not self.queue and all(s.free for s in self.slots)
