"""Async transfer engine: all block movement, batched once per round.

Before this module each movement kind had its own ad-hoc path —
``pipeline.make_block_copy`` was a one-shot CoW device copy the engine
flushed per call site, and swap-out/swap-in did not exist. The
:class:`TransferEngine` is now the single owner of block movement over the
pool:

* **CoW copies** (device → device): ``copy()`` enqueues (src, dst) pairs;
  one compiled pool-copy call per engine round moves them all.
* **Swap-in** (host → device): ``swap_in()`` enqueues a spilled payload for
  injection into a freshly allocated device block (prefix-cache restores,
  retraction restores).
* **Swap-out** (device → host): ``swap_out()`` extracts payloads *eagerly* —
  reclamation needs the device block back on the free list in the same
  Python call (the allocator retry follows immediately), and extraction is
  a read, so there is nothing to defer.

In-flight rule
--------------
Between enqueue and :meth:`flush`, every copy/swap-in *destination* block is
**in-flight**: its pool bytes do not yet hold the intended K/V, so no
compute call may read it and no caller may mutate, extract, or retract it
(:meth:`in_flight` is the query; the serve engine asserts the rule before
every pipeline call and skips in-flight slots as retraction victims).
``flush()`` applies swap-ins first, then CoW copies — a copy whose *source*
was restored this same round therefore reads the injected bytes, never the
stale pool content.

Kernels come from ``pipeline.make_transfer_kernels``; ``kernels=None`` runs
the engine in pure-bookkeeping mode (payloads are ``None``) so host-side
scheduling tests exercise the full lifecycle without jax.
"""
from __future__ import annotations

from typing import List

import numpy as np


class TransferEngine:
    """Batched-per-round block mover over the (trial, shard)-partitioned pool.

    ``n_trials``/``n_shards`` recover the (k, shard) coordinates of a pool
    partition (p = k * n_shards + shard) so enqueued ops can be packed into
    the compiled kernels' (K, dp, C) id layout at flush time. ``bind()``
    attaches the cache accessors (the engine owns the live cache pytree;
    flush reads and replaces it through these).
    """

    def __init__(self, n_trials: int, n_shards: int, kernels=None):
        self.n_trials = n_trials
        self.n_shards = n_shards
        self.kernels = kernels
        self._get_cache = None
        self._set_cache = None
        self._copies: List[tuple] = []  # (partition, src, dst)
        self._swap_ins: List[tuple] = []  # (partition, dst, payload)
        self._in_flight: set = set()  # {(partition, block)} — dsts pre-flush
        self.cow_copies = 0
        self.swap_in_blocks = 0
        self.swap_out_blocks = 0
        self.round_peak = 0  # max concurrent in-flight dsts since last take

    def bind(self, get_cache, set_cache) -> None:
        self._get_cache = get_cache
        self._set_cache = set_cache

    # -- queries -------------------------------------------------------------

    def in_flight(self, partition: int, block: int) -> bool:
        """True while ``block`` is a pending transfer destination: its pool
        bytes are not yet valid — never read, mutate, or retract it."""
        return (partition, block) in self._in_flight

    def pending(self) -> int:
        return len(self._copies) + len(self._swap_ins)

    def take_round_peak(self) -> int:
        """Peak in-flight destination count since the last call — the
        per-round transfer-pressure sample of the tracer's round record."""
        peak, self.round_peak = self.round_peak, len(self._in_flight)
        return peak

    # -- enqueue -------------------------------------------------------------

    def copy(self, partition: int, src: int, dst: int) -> None:
        """Enqueue a CoW pool copy dst := src (both partition-local ids).
        ``dst`` is in-flight until flush; ``src`` stays readable."""
        self._copies.append((partition, src, dst))
        self._in_flight.add((partition, dst))
        self.round_peak = max(self.round_peak, len(self._in_flight))
        self.cow_copies += 1

    def swap_in(self, partition: int, dst: int, payload) -> None:
        """Enqueue a host → device restore of one spilled payload into pool
        block ``dst`` (freshly allocated by the caller); ``dst`` is in-flight
        until flush."""
        self._swap_ins.append((partition, dst, payload))
        self._in_flight.add((partition, dst))
        self.round_peak = max(self.round_peak, len(self._in_flight))
        self.swap_in_blocks += 1

    # -- eager device → host -------------------------------------------------

    def swap_out(self, partition: int, ids) -> list:
        """Extract the K/V payloads of pool blocks ``ids`` (device → host),
        eagerly — the caller frees the device blocks right after, so the
        bytes must be off the pool before this returns. Read-only: shared
        blocks (refcount > 1) may be extracted safely. Returns one opaque
        payload per id (``None`` each in bookkeeping mode)."""
        ids = list(ids)
        self.swap_out_blocks += len(ids)
        if self.kernels is None or not ids:
            return [None] * len(ids)
        k, shard = divmod(partition, self.n_shards)
        return self.kernels.extract(self._get_cache(), k, shard, ids)

    # -- flush ---------------------------------------------------------------

    def _pack(self, ops) -> tuple:
        """(K, n_shards, C) -1-padded local-id arrays for the copy kernel;
        C bucketed to powers of two to bound compile shapes."""
        per: dict = {}
        for p, src, dst in ops:
            per.setdefault(divmod(p, self.n_shards), []).append((src, dst))
        c = 1
        while c < max(len(v) for v in per.values()):
            c *= 2
        s = np.full((self.n_trials, self.n_shards, c), -1, np.int32)
        d = np.full((self.n_trials, self.n_shards, c), -1, np.int32)
        for (k, sh), pairs in per.items():
            for j, (s_, d_) in enumerate(pairs):
                s[k, sh, j], d[k, sh, j] = s_, d_
        return s, d

    def flush(self) -> int:
        """Apply every enqueued op to the live cache — swap-ins first (a CoW
        source restored this round must read injected bytes, not stale pool
        content), then the batched CoW copy call — and clear the in-flight
        set. Returns the number of ops applied."""
        n = self.pending()
        if n == 0:
            return 0
        if self.kernels is not None:
            cache = self._get_cache()
            if self._swap_ins:
                per: dict = {}
                for p, dst, payload in self._swap_ins:
                    per.setdefault(divmod(p, self.n_shards),
                                   []).append((dst, payload))
                for (k, shard), items in per.items():
                    cache = self.kernels.inject(
                        cache, k, shard, [d for d, _ in items],
                        [pl_ for _, pl_ in items])
            if self._copies:
                src, dst = self._pack(self._copies)
                cache = self.kernels.copy(cache, src, dst)
            self._set_cache(cache)
        self._copies = []
        self._swap_ins = []
        self._in_flight = set()
        return n


def make_null_transfer(n_trials: int = 1,
                       n_shards: int = 1) -> "TransferEngine":
    """Bookkeeping-only transfer engine (no kernels, payloads = None) for
    host-side scheduling tests of the tiered store lifecycle."""
    return TransferEngine(n_trials, n_shards, kernels=None)


__all__ = ["TransferEngine", "make_null_transfer"]
