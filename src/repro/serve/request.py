"""Request/completion datatypes and arrival traces for the serve engine.

A *trace* is a list of :class:`Request` with monotone ``arrival`` times in
engine-tick units; ``poisson_trace`` synthesizes the open-loop arrival
process the benchmarks replay, and ``save_trace``/``load_trace`` round-trip
traces through JSONL so a measured production stream can be replayed with
``python -m repro.launch.serve --trace path.jsonl``.

Multi-architecture co-serving: every request names the model variant it is
addressed to via ``arch`` — the trial row k of the gang's (k, m, b) slot
grid. A single-arch trace is simply one where every ``arch`` is 0.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request: a prompt and a greedy-generation budget.

    ``arch`` routes the request to one model variant of the co-serving gang
    (trial row k); ``deadline`` is an absolute engine tick the deadline-aware
    batcher policy orders by (None = best-effort).
    """

    rid: int
    prompt: np.ndarray  # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival: float = 0.0  # engine tick at which the request becomes visible
    arch: int = 0  # trial row (model variant) this request is addressed to
    deadline: Optional[float] = None  # absolute tick for the deadline policy

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError(f"request {self.rid}: prompt must be a non-empty "
                             f"1-d token array, got shape {self.prompt.shape}")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens must be >=1")
        if self.arch < 0:
            raise ValueError(f"request {self.rid}: arch must be >= 0")

    def clone(self) -> "Request":
        """Independent copy for replaying one trace through several engines
        (engines never mutate requests, but the prompt array is shared state
        a caller should not have to reason about)."""
        return Request(self.rid, self.prompt.copy(), self.max_new_tokens,
                       self.arrival, self.arch, self.deadline)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_len(self) -> int:
        """Cache rows the request needs: prompt + generated (the final token
        is emitted by the head and never written back)."""
        return self.prompt_len + self.max_new_tokens - 1

    @property
    def draft_total_len(self) -> int:
        """Max cache depth a paired drafter row reaches for this request
        (gang speculation — the batcher reserves this many positions of
        drafter capacity at admission). The drafter catches up to the
        target's committed stream and proposes at most gamma_eff =
        remaining - 1 tokens ahead, so its depth is bounded by
        ``total_len - 1``: it never drafts past the position whose token
        would be the request's final (never-verified) output."""
        return max(self.total_len - 1, 1)


@dataclasses.dataclass
class Completion:
    """Per-request result + scheduling timestamps (engine ticks)."""

    rid: int
    prompt_len: int
    tokens: list  # generated token ids (greedy), len == max_new_tokens
    arrival: float
    admitted_tick: int
    finished_tick: int
    arch: int = 0
    first_token_tick: int = -1  # tick the head emitted the first token

    @property
    def latency_ticks(self) -> float:
        return self.finished_tick - self.arrival

    @property
    def queue_ticks(self) -> float:
        return self.admitted_tick - self.arrival

    @property
    def ttft_ticks(self) -> float:
        """Time to first token: arrival -> first head emission."""
        if self.first_token_tick < 0:
            return self.latency_ticks
        return self.first_token_tick - self.arrival

    @property
    def tpot_ticks(self) -> float:
        """Mean time per output token after the first (decode cadence)."""
        n = len(self.tokens)
        if n <= 1 or self.first_token_tick < 0:
            return 0.0
        return (self.finished_tick - self.first_token_tick) / (n - 1)


def poisson_trace(n_requests: int, rate: float, vocab: int,
                  prompt_lens: Sequence[int] = (8, 12, 16),
                  gen_lens: Sequence[int] = (4, 8, 12),
                  seed: int = 0, n_arches: int = 1,
                  arch_weights: Optional[Sequence[float]] = None,
                  deadline_slack: float = 0.0) -> list:
    """Open-loop Poisson arrivals with staggered prompt/gen lengths.

    ``rate`` is requests per engine tick. Prompt/gen lengths are drawn
    uniformly from the given sets — small sets on purpose, so the engine
    compiles few distinct chunk shapes (production would bucket lengths
    the same way). ``n_arches`` > 1 draws each request's target model
    variant from ``arch_weights`` (uniform when omitted) — the mixed
    request stream a co-serving gang routes across its trial rows.
    ``deadline_slack`` > 0 stamps each request with
    ``arrival + slack * (prompt_len + gen_len)`` for the deadline policy.
    """
    rng = np.random.default_rng(seed)
    if arch_weights is not None:
        w = np.asarray(arch_weights, np.float64)
        if w.shape[0] != n_arches or (w < 0).any() or w.sum() <= 0:
            raise ValueError(f"arch_weights must be {n_arches} non-negative "
                             f"weights with a positive sum, got {arch_weights}")
        w = w / w.sum()
    else:
        w = None
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / max(rate, 1e-9)))
        pl = int(rng.choice(list(prompt_lens)))
        gl = int(rng.choice(list(gen_lens)))
        arch = int(rng.choice(n_arches, p=w)) if n_arches > 1 else 0
        dl = t + deadline_slack * (pl + gl) if deadline_slack > 0 else None
        prompt = rng.integers(0, vocab, (pl,)).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=gl,
                            arrival=t, arch=arch, deadline=dl))
    return reqs


def save_trace(path: str, requests: Sequence[Request]) -> None:
    with open(path, "w") as f:
        for r in requests:
            rec = {"rid": r.rid, "prompt": r.prompt.tolist(),
                   "max_new_tokens": r.max_new_tokens, "arrival": r.arrival}
            if r.arch:
                rec["arch"] = r.arch
            if r.deadline is not None:
                rec["deadline"] = r.deadline
            f.write(json.dumps(rec) + "\n")


def load_trace(path: str) -> list:
    reqs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            d = json.loads(line)
            dl = d.get("deadline")
            reqs.append(Request(rid=int(d["rid"]),
                                prompt=np.asarray(d["prompt"], np.int32),
                                max_new_tokens=int(d["max_new_tokens"]),
                                arrival=float(d.get("arrival", 0.0)),
                                arch=int(d.get("arch", 0)),
                                deadline=float(dl) if dl is not None else None))
    return reqs
