"""Paged KV-cache bookkeeping: block pool, free-list allocator, block tables.

The dense serve cache reserves one ``max_seq``-length strip per slot cell, so
``plan_serve_capacity`` must admit by worst-case length and a short request
strands the HBM behind its strip. Paging (vLLM-style) replaces the strips
with one shared pool of fixed-size blocks per layer; each live request owns a
*block table* — the ordered list of physical block ids backing its logical
token positions — which grows one block at a time as chunked prefill and
decode append tokens (alloc-on-append) and is returned to the free list the
round the request completes (free-on-completion).

Everything here is host-side scheduling state (plain Python, no jax): the
device side consumes the tables as ``(rows, max_blocks)`` int32 arrays whose
entries are *local* physical ids. When the batch rows are sharded over the
data/pod axes, each shard owns an equal slice of the pool and the allocator
is split into one **partition** per shard — rows allocate only from their
shard's partition, so the ids written into the table index that shard's
local pool slice directly and the SPMD kernel needs no id translation.

Admission against the pool is *exact* in this engine (generation always runs
to the request's ``max_new_tokens`` budget, so the final footprint is known
at enqueue time): the batcher commits ``blocks_for(total_len)`` per live
request and defers admission when the committed total would exceed the
partition's pool — the backpressure that replaces worst-case ``max_seq``
reservation. ``overcommit`` > 1 relaxes the committed-total gate (statistical
packing); the allocator then backstops with per-append failures that stall a
row until a completion frees blocks.
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional

import numpy as np


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to back ``n_tokens`` cache rows."""
    if n_tokens <= 0:
        return 0
    return -(-n_tokens // block_size)


class BlockAllocator:
    """Free-list allocator over a pool of ``n_blocks`` fixed-size blocks.

    ``n_partitions`` > 1 splits the pool into equal per-data-shard slices;
    every id handed out is local to its partition (0..n_blocks/P - 1).
    Allocation is all-or-nothing and FIFO: freed blocks go to the tail of the
    free list and are reused oldest-first, which keeps recycling deterministic
    (tested) and spreads writes over the pool.
    """

    def __init__(self, n_blocks: int, block_size: int, n_partitions: int = 1):
        if n_blocks < 1 or block_size < 1 or n_partitions < 1:
            raise ValueError("n_blocks, block_size, n_partitions must be >= 1")
        if n_blocks % n_partitions:
            raise ValueError(f"n_blocks={n_blocks} not divisible by "
                             f"n_partitions={n_partitions}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.n_partitions = n_partitions
        self.blocks_per_partition = n_blocks // n_partitions
        self._free = [deque(range(self.blocks_per_partition))
                      for _ in range(n_partitions)]
        self._live = [set() for _ in range(n_partitions)]

    # -- queries -------------------------------------------------------------

    def free_blocks(self, partition: Optional[int] = None) -> int:
        if partition is None:
            return sum(len(f) for f in self._free)
        return len(self._free[partition])

    def used_blocks(self, partition: Optional[int] = None) -> int:
        if partition is None:
            return sum(len(s) for s in self._live)
        return len(self._live[partition])

    def all_free(self) -> bool:
        return self.used_blocks() == 0

    # -- alloc / free --------------------------------------------------------

    def alloc(self, n: int, partition: int = 0) -> Optional[List[int]]:
        """Pop ``n`` blocks from the partition's free list, oldest-first.

        All-or-nothing: returns None (and changes nothing) when fewer than
        ``n`` blocks are free — the caller defers admission or stalls the
        append until a completion frees blocks.
        """
        free = self._free[partition]
        if n < 0:
            raise ValueError(f"alloc({n}): negative block count")
        if len(free) < n:
            return None
        ids = [free.popleft() for _ in range(n)]
        self._live[partition].update(ids)
        return ids

    def free(self, ids, partition: int = 0) -> None:
        """Return blocks to the tail of the partition's free list.

        Raises ValueError on double-free or unknown ids — a table that frees
        twice would let two requests share a physical block silently.
        """
        live = self._live[partition]
        for i in ids:
            if i not in live:
                raise ValueError(f"double free of block {i} "
                                 f"(partition {partition})")
            live.discard(i)
            self._free[partition].append(i)


class BlockTable:
    """Per-request view of the pool: ordered physical ids backing positions
    [0, n_tokens). Grows via :meth:`ensure` (alloc-on-append) and returns its
    blocks with :meth:`close` (free-on-completion).
    """

    def __init__(self, allocator: BlockAllocator, partition: int = 0):
        self.allocator = allocator
        self.partition = partition
        self.blocks: List[int] = []
        self._closed = False

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    def capacity_tokens(self) -> int:
        return len(self.blocks) * self.allocator.block_size

    def ensure(self, n_tokens: int) -> bool:
        """Grow the table to cover ``n_tokens`` positions; False = pool
        exhausted (nothing allocated — retry after a completion frees blocks).
        """
        if self._closed:
            raise RuntimeError("ensure() on a closed block table")
        need = blocks_for(n_tokens, self.allocator.block_size) - len(self.blocks)
        if need <= 0:
            return True
        got = self.allocator.alloc(need, self.partition)
        if got is None:
            return False
        self.blocks.extend(got)
        return True

    def close(self) -> None:
        """Free every block. Idempotent (a second close is a no-op, the
        allocator itself rejects genuine double-frees)."""
        if self._closed:
            return
        self.allocator.free(self.blocks, self.partition)
        self.blocks = []
        self._closed = True

    def as_row(self, max_blocks: int) -> np.ndarray:
        """(max_blocks,) int32 device view, unallocated tail = -1."""
        if len(self.blocks) > max_blocks:
            raise ValueError(f"table holds {len(self.blocks)} blocks > "
                             f"max_blocks={max_blocks}")
        row = np.full((max_blocks,), -1, np.int32)
        row[:len(self.blocks)] = self.blocks
        return row
