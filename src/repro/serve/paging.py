"""Paged KV-cache bookkeeping: block pool, ref-counted allocator, block tables.

The dense serve cache reserves one ``max_seq``-length strip per slot cell, so
``plan_serve_capacity`` must admit by worst-case length and a short request
strands the HBM behind its strip. Paging (vLLM-style) replaces the strips
with one shared pool of fixed-size blocks per layer; each live request owns a
*block table* — the ordered list of physical block ids backing its logical
token positions — which grows one block at a time as chunked prefill and
decode append tokens (alloc-on-append) and drops its references the round
the request completes.

Everything here is host-side scheduling state (plain Python, no jax): the
device side consumes the tables as ``(rows, max_blocks)`` int32 arrays whose
entries are *local* physical ids. When the batch rows are sharded over the
data/pod axes, each shard owns an equal slice of the pool and the allocator
is split into one **partition** per shard — rows allocate only from their
shard's partition, so the ids written into the table index that shard's
local pool slice directly and the SPMD kernel needs no id translation.

Admission against the pool is *exact* in this engine (generation always runs
to the request's ``max_new_tokens`` budget, so the final footprint is known
at enqueue time): the batcher commits ``blocks_for(total_len)`` per live
request and defers admission when the committed total would exceed the
partition's pool — the backpressure that replaces worst-case ``max_seq``
reservation. ``overcommit`` > 1 relaxes the committed-total gate
(statistical packing); the engine then backstops per-append failures by
*retracting* the lowest-priority running request instead of stalling (see
the retract/restore state machine below).

Two-tier lifecycle (device ⊂ store; see serve/store.py, serve/transfer.py)
--------------------------------------------------------------------------
The device pool is the fast tier of a :class:`~repro.serve.store.BlockStore`
that also owns a host-memory tier of spilled payloads. Device blocks are a
*cache* over the store, not a hard capacity wall:

* a block is **device-resident** while its id is live in the allocator; it
  becomes **host-resident** when the transfer engine extracts its K/V to a
  host block and the device id is freed (prefix-cache spills, retraction
  swap-outs), and device-resident again when a restore allocates a fresh id
  and enqueues a swap-in;
* every pressure-driven reclamation flows through ``BlockStore.reclaim`` —
  ``BlockTable`` never talks to the prefix cache directly — so eviction
  ordering is one LRU walk across both tiers instead of per-call-site.

**Transfer-in-flight rule**: between enqueue and the transfer engine's
per-round ``flush()``, every copy/swap-in *destination* block holds stale
pool bytes. No compute call may read it, nothing may mutate or extract it,
and a slot whose table contains one is not a valid retraction victim. The
serve engine asserts this before every pipeline call.

**Retract/restore state machine** (overcommit > 1 only):

  RUNNING ──pool exhausted, youngest-first──► RETRACTED ──re-admitted──►
  RESTORING ──transfer flush──► RUNNING

  A retracted decode-phase request either *swap-restores* (its table's
  payloads were extracted to pinned host blocks at retraction; restore
  allocates fresh device blocks and swap-ins them — no recompute) or
  *recompute-restores* (host tier full/disabled: replay prompt + generated
  tokens as a teacher-forced prefill; the replay's final head output must
  equal the last generated token). Both paths yield tokens bit-identical to
  an un-preempted run. A retracted prefill-phase request simply requeues.

Refcount / copy-on-write invariants (prefix sharing, see prefix_cache.py)
-------------------------------------------------------------------------
Blocks are **ref-counted** so one physical block can back the same logical
prefix of several requests at once (and of the radix prefix cache between
requests). The invariants every caller must preserve:

  1. ``alloc`` hands out blocks at refcount 1; ``decref`` releases one
     reference and the block returns to the free list only at refcount 0
     (``free`` is the legacy alias for ``decref``). A block is *live* while
     its refcount is >= 1 and is never handed out again until it drops to 0.
  2. Decref of a non-live block raises (double-free guard): a table that
     releases twice would let two requests share a block silently.
  3. **Writers own their blocks exclusively**: no K/V write may target a
     block whose refcount is > 1. Shared blocks are read-only; a request
     about to write into a shared block must first *fork* it
     (:meth:`BlockTable.fork_shared`) — allocate a fresh block, enqueue a
     device pool copy on the transfer engine, and drop its reference to the
     shared original (copy-on-write). The device scatter itself never
     touches positions below a row's ``kv_offset``, so full shared prefix
     blocks are structurally write-free; only the partially-filled *tail*
     block of a prefix hit can ever need the fork.
  4. Shared reads are safe without copies: the gather path
     (``blocks.paged_kv_update``) reads whole blocks through each row's
     table and masks the garbage tail via ``kv_len``, so two tables holding
     the same block id read the same bytes. Device → host extraction is a
     read too: swapping out a shared block never violates invariant 3.
  5. The radix prefix cache holds exactly one reference per cached
     device-resident block; reclamation (the store's LRU walk) may
     therefore spill or destroy only blocks at refcount 1 — a cached block
     also referenced by a live request is pinned until that request
     completes. Host-resident cache nodes hold no device reference at all.
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional

import numpy as np


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to back ``n_tokens`` cache rows."""
    if n_tokens <= 0:
        return 0
    return -(-n_tokens // block_size)


class BlockAllocator:
    """Ref-counted free-list allocator over ``n_blocks`` fixed-size blocks.

    ``n_partitions`` > 1 splits the pool into equal per-data-shard slices;
    every id handed out is local to its partition (0..n_blocks/P - 1).
    Allocation is all-or-nothing and FIFO: blocks that drop to refcount 0 go
    to the tail of the free list and are reused oldest-first, which keeps
    recycling deterministic (tested) and spreads writes over the pool.
    """

    def __init__(self, n_blocks: int, block_size: int, n_partitions: int = 1):
        if n_blocks < 1 or block_size < 1 or n_partitions < 1:
            raise ValueError("n_blocks, block_size, n_partitions must be >= 1")
        if n_blocks % n_partitions:
            raise ValueError(f"n_blocks={n_blocks} not divisible by "
                             f"n_partitions={n_partitions}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.n_partitions = n_partitions
        self.blocks_per_partition = n_blocks // n_partitions
        self._free = [deque(range(self.blocks_per_partition))
                      for _ in range(n_partitions)]
        self._ref = [dict() for _ in range(n_partitions)]  # id -> refcount

    # -- queries -------------------------------------------------------------

    def free_blocks(self, partition: Optional[int] = None) -> int:
        if partition is None:
            return sum(len(f) for f in self._free)
        return len(self._free[partition])

    def used_blocks(self, partition: Optional[int] = None) -> int:
        if partition is None:
            return sum(len(r) for r in self._ref)
        return len(self._ref[partition])

    def all_free(self) -> bool:
        return self.used_blocks() == 0

    def ref_count(self, block: int, partition: int = 0) -> int:
        """Current refcount of a block (0 = free)."""
        return self._ref[partition].get(block, 0)

    # -- alloc / ref / free --------------------------------------------------

    def alloc(self, n: int, partition: int = 0) -> Optional[List[int]]:
        """Pop ``n`` blocks from the partition's free list, oldest-first,
        each at refcount 1.

        All-or-nothing: returns None (and changes nothing) when fewer than
        ``n`` blocks are free — the caller defers admission, evicts cached
        prefixes, or stalls the append until references drop.
        """
        free = self._free[partition]
        if n < 0:
            raise ValueError(f"alloc({n}): negative block count")
        if len(free) < n:
            return None
        ids = [free.popleft() for _ in range(n)]
        ref = self._ref[partition]
        for i in ids:
            ref[i] = 1
        return ids

    def incref(self, ids, partition: int = 0) -> None:
        """Add one reference per id (prefix sharing: a second request — or
        the radix cache — adopts an already-live block read-only)."""
        ref = self._ref[partition]
        for i in ids:
            if i not in ref:
                raise ValueError(f"incref of free block {i} "
                                 f"(partition {partition})")
            ref[i] += 1

    def decref(self, ids, partition: int = 0) -> List[int]:
        """Drop one reference per id; blocks reaching refcount 0 return to
        the tail of the partition's free list (and are reported back).

        Raises ValueError on non-live ids — a table that releases twice
        would let two requests share a physical block silently.
        """
        ref = self._ref[partition]
        freed = []
        for i in ids:
            if i not in ref:
                raise ValueError(f"double free of block {i} "
                                 f"(partition {partition})")
            ref[i] -= 1
            if ref[i] == 0:
                del ref[i]
                self._free[partition].append(i)
                freed.append(i)
        return freed

    # legacy alias (PR-3 API): free-on-completion is now a refcount drop
    free = decref

    def rollback(self, ids, partition: int = 0) -> None:
        """Inverse of :meth:`alloc`, for speculative-decoding rollback:
        return ``ids`` to the *head* of the partition's free list in their
        original allocation order, so the allocator ends up bit-identical to
        never having handed them out. ``decref`` cannot do this — it recycles
        through the free-list tail, which would permute every later
        allocation relative to the never-proposed schedule.

        Every id must be exclusively owned (refcount exactly 1): rolling
        back a block another holder still references (a shared prefix block,
        a cached block) would corrupt that holder's view, and rolling back a
        free block is a double-free. Raises ValueError without touching
        anything on violation (all-or-nothing, like ``alloc``)."""
        ref = self._ref[partition]
        ids = list(ids)
        for i in ids:
            if ref.get(i, 0) != 1:
                raise ValueError(
                    f"rollback of block {i} (partition {partition}) at "
                    f"refcount {ref.get(i, 0)}: only exclusively-owned "
                    f"blocks can be rolled back")
        for i in ids:
            del ref[i]
        self._free[partition].extendleft(reversed(ids))


class BlockTable:
    """Per-request view of the pool: ordered physical ids backing positions
    [0, n_tokens). Grows via :meth:`ensure` (alloc-on-append) and drops its
    references with :meth:`close` (on completion).

    With a prefix cache, the leading entries may be *shared* blocks seeded
    from a radix hit (:meth:`seed`); the caller must already hold a
    reference on them (``PrefixCache.acquire``), which :meth:`close`
    releases uniformly. Allocation pressure is routed through the tiered
    ``store`` (``BlockStore.reclaim`` — the single LRU walk across the
    device and host tiers); passing a bare ``cache`` (the pre-store API,
    kept for host-side tests) routes through that cache's own store.
    """

    def __init__(self, allocator: BlockAllocator, partition: int = 0,
                 cache=None, store=None):
        self.allocator = allocator
        self.partition = partition
        if store is None and cache is not None:
            store = cache.store  # legacy wiring: the cache carries its store
        self.store = store  # Optional[BlockStore] — reclamation on pressure
        self.blocks: List[int] = []
        self._closed = False

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    def capacity_tokens(self) -> int:
        return len(self.blocks) * self.allocator.block_size

    def seed(self, shared_ids) -> None:
        """Prepend shared prefix blocks (a radix-cache hit). Must be called
        on an empty table, and the caller must hold one reference per id —
        :meth:`close` decrefs every entry uniformly."""
        if self.blocks or self._closed:
            raise RuntimeError("seed() on a non-empty or closed block table")
        self.blocks.extend(shared_ids)

    def _alloc(self, need: int) -> Optional[List[int]]:
        got = self.allocator.alloc(need, self.partition)
        if got is None and self.store is not None:
            # reclaim through the tiered store (spill/evict LRU unreferenced
            # cached prefixes across both tiers), then retry once
            self.store.reclaim(self.partition, need)
            got = self.allocator.alloc(need, self.partition)
        return got

    def ensure(self, n_tokens: int) -> bool:
        """Grow the table to cover ``n_tokens`` positions; False = pool
        exhausted (nothing allocated — retry after references drop).
        """
        if self._closed:
            raise RuntimeError("ensure() on a closed block table")
        need = blocks_for(n_tokens, self.allocator.block_size) - len(self.blocks)
        if need <= 0:
            return True
        got = self._alloc(need)
        if got is None:
            return False
        self.blocks.extend(got)
        return True

    def fork_shared(self, t0: int, t1: int) -> Optional[list]:
        """Copy-on-write: replace every *shared* block (refcount > 1)
        overlapping token positions [t0, t1) with a fresh private block.

        Returns the [(src, dst), ...] physical-id pairs the caller must
        device-copy (pool row dst := pool row src) **before** the write that
        motivated the fork, or None when the pool cannot back the fork right
        now (nothing changed — stall and retry). Two-phase: the replacement
        ids are allocated all-or-nothing first, so a failed fork never
        leaves an un-copied private block in the table.
        """
        if self._closed:
            raise RuntimeError("fork_shared() on a closed block table")
        bs = self.allocator.block_size
        idxs = [i for i in range(t0 // bs, blocks_for(t1, bs))
                if i < len(self.blocks)
                and self.allocator.ref_count(self.blocks[i],
                                             self.partition) > 1]
        if not idxs:
            return []
        got = self._alloc(len(idxs))
        if got is None:
            return None
        pairs = []
        for i, dst in zip(idxs, got):
            src = self.blocks[i]
            self.allocator.decref([src], self.partition)
            self.blocks[i] = dst
            pairs.append((src, dst))
        return pairs

    def truncate(self, n_tokens: int) -> List[int]:
        """Partial-row rollback: shrink the table to the blocks backing
        positions [0, n_tokens), un-allocating the tail blocks a rejected
        speculation grew it by. The dropped blocks must be exclusively owned
        — speculative writes only ever target the row's write range, which
        the engine proves private (``_assert_clean``) before the verify call
        — and they return to the free-list *head* in order
        (:meth:`BlockAllocator.rollback`), so allocator state is
        bit-identical to never having grown the table. Shared/seeded prefix
        blocks sit structurally below any speculation offset and are never
        touched; the retained tail block may hold stale positions >=
        ``n_tokens``, which every later read masks via kv_len and every
        later write overwrites. Returns the dropped ids."""
        if self._closed:
            raise RuntimeError("truncate() on a closed block table")
        keep = blocks_for(n_tokens, self.allocator.block_size)
        if keep >= len(self.blocks):
            return []
        drop = self.blocks[keep:]
        if self.store is not None:
            # chokepoint: the store asserts none of the ids is an in-flight
            # transfer destination before handing them back
            self.store.rollback(self.partition, drop)
        else:
            self.allocator.rollback(drop, self.partition)
        self.blocks = self.blocks[:keep]
        return drop

    def close(self) -> None:
        """Drop this table's reference on every block. Idempotent (a second
        close is a no-op, the allocator itself rejects genuine
        double-frees). Shared blocks survive under their other references
        (radix cache / other requests); private blocks return to the free
        list."""
        if self._closed:
            return
        self.allocator.decref(self.blocks, self.partition)
        self.blocks = []
        self._closed = True

    def as_row(self, max_blocks: int) -> np.ndarray:
        """(max_blocks,) int32 device view, unallocated tail = -1."""
        if len(self.blocks) > max_blocks:
            raise ValueError(f"table holds {len(self.blocks)} blocks > "
                             f"max_blocks={max_blocks}")
        row = np.full((max_blocks,), -1, np.int32)
        row[:len(self.blocks)] = self.blocks
        return row
