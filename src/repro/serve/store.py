"""Tiered block store: the device pool fronted by a host-memory tier.

``BlockAllocator`` (serve/paging.py) only knows the device free list, and
before this module every caller under memory pressure had its own idea of
what to reclaim — ``BlockTable.ensure`` asked the prefix cache for
partition-local room, retraction freed blocks outright, and evicted radix
blocks were destroyed. :class:`BlockStore` centralizes that ownership story:
device HBM is a *cache* over a larger host tier (Hydra's spilled
model-parallelism applied to serving), and every pressure-driven reclamation
flows through :meth:`reclaim`, so eviction ordering is LRU across *both*
tiers instead of per-call-site.

Tiers
-----
* **Device tier** — the ref-counted :class:`~repro.serve.paging.BlockAllocator`
  pool partitions. Blocks here are addressable by the SPMD kernels through
  block tables.
* **Host tier** — up to ``host_blocks`` spilled blocks *per partition*, each
  holding the raw K/V payload of one pool block (extracted by the
  :class:`~repro.serve.transfer.TransferEngine`). Host blocks are reached
  only by swapping back into the device tier; they come in two kinds:

  - *cache spills* (``owner`` = a radix node): unreferenced prefix-cache
    leaves moved out of HBM by :meth:`reclaim`; evictable LRU when the host
    tier itself fills (the node is then destroyed — the old single-tier
    behavior, now the last resort instead of the first).
  - *retract payloads* (``pinned=True``): a preempted request's KV, owned by
    its pending restore — never evicted, freed when the restore swaps them
    back in.

The store itself is host-side bookkeeping; actual byte movement is the
transfer engine's job (``self.transfer``). With no transfer engine attached
(pure scheduling tests) the host tier still tracks capacity but payloads are
opaque ``None`` placeholders.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.tracer import NULL_TRACER
from repro.serve.paging import BlockAllocator


@dataclasses.dataclass
class HostBlock:
    """One spilled block in the host tier."""

    payload: object  # raw K/V bytes (kernel-defined); None in host-only tests
    owner: object = None  # radix node for cache spills, None for retracts
    pinned: bool = False  # retract payloads: owned by a pending restore
    last_used: int = 0


class BlockStore:
    """Two-tier block lifecycle manager (device pool + host spill tier).

    ``host_blocks`` is the host-tier capacity per pool partition; 0 disables
    the host tier entirely (spills degrade to destruction, retraction falls
    back to recompute-based restore). ``spill`` gates whether cache eviction
    may use the host tier at all (``--no-spill``).

    Wiring: the engine attaches a :class:`TransferEngine` via ``transfer``;
    :class:`~repro.serve.prefix_cache.PrefixCache` attaches itself as
    ``cache`` on construction (it owns the LRU structure that
    :meth:`reclaim` walks).
    """

    def __init__(self, allocator: BlockAllocator, host_blocks: int = 0,
                 spill: bool = True, transfer=None):
        if host_blocks < 0:
            raise ValueError(f"host_blocks must be >= 0, got {host_blocks}")
        self.allocator = allocator
        self.host_capacity = host_blocks
        self.spill = bool(spill) and host_blocks > 0
        self.transfer = transfer
        self.cache = None  # PrefixCache attaches itself (reclaim LRU walk)
        self._host = [dict() for _ in range(allocator.n_partitions)]
        self._next_hid = [0] * allocator.n_partitions
        self._clock = 0
        self.host_evictions = 0  # host blocks destroyed under host pressure
        self.rollbacks = 0  # device blocks un-allocated by spec rollback
        self.trace = NULL_TRACER  # engine swaps in its tracer when tracing

    # -- queries -------------------------------------------------------------

    @property
    def n_partitions(self) -> int:
        return self.allocator.n_partitions

    def host_used(self, partition: Optional[int] = None) -> int:
        if partition is None:
            return sum(len(h) for h in self._host)
        return len(self._host[partition])

    def host_free(self, partition: int) -> int:
        return self.host_capacity - len(self._host[partition])

    # -- device tier ---------------------------------------------------------

    def alloc(self, n: int, partition: int = 0):
        """Device alloc with cross-tier reclamation on pressure: when the
        free list cannot back ``n`` blocks, spill (or destroy) LRU
        unreferenced cached blocks via :meth:`reclaim` and retry once.
        Returns the ids or None (nothing changed) — same contract as
        ``BlockAllocator.alloc``."""
        got = self.allocator.alloc(n, partition)
        if got is None:
            self.reclaim(partition, n)
            got = self.allocator.alloc(n, partition)
        return got

    def rollback(self, partition: int, ids) -> None:
        """Speculation rollback chokepoint (``BlockTable.truncate``): assert
        none of the blocks is an in-flight transfer destination — an
        in-flight block's bytes are not addressable, so it cannot have been
        written by the verify call being rolled back, and un-allocating it
        would hand the pending transfer's destination to a new owner — then
        return them to the device free-list head via
        :meth:`BlockAllocator.rollback` (bit-identical pool state)."""
        ids = list(ids)
        if self.transfer is not None:
            for i in ids:
                if self.transfer.in_flight(partition, i):
                    raise RuntimeError(
                        f"rollback of in-flight block {i} (partition "
                        f"{partition}): pending transfer destinations "
                        f"cannot be un-allocated")
        self.allocator.rollback(ids, partition)
        self.rollbacks += len(ids)

    def reclaim(self, partition: int, need: int) -> int:
        """The single chokepoint for pressure-driven reclamation: delegate
        to the prefix cache's LRU walk (spill-first when the host tier has
        room, destroy as last resort). Returns blocks reclaimed."""
        if self.cache is None:
            return 0
        return self.cache.make_room(partition, need)

    # -- host tier -----------------------------------------------------------

    def host_can_put(self, partition: int) -> bool:
        """Whether one more host block fits (possibly by evicting an
        unpinned cache spill) — checked before paying for an extraction."""
        if self.host_capacity <= 0:
            return False
        if len(self._host[partition]) < self.host_capacity:
            return True
        return self._host_victim(partition) is not None

    def host_put(self, partition: int, payload, owner=None,
                 pinned: bool = False) -> Optional[int]:
        """Adopt one block's payload into the host tier; evicts LRU unpinned
        cache spills to make room (their radix nodes are destroyed — the
        host tier is itself a cache). Returns the host id, or None when the
        tier is full of pinned/unevictable blocks (caller falls back to the
        destroy / recompute path)."""
        if self.host_capacity <= 0:
            return None
        while len(self._host[partition]) >= self.host_capacity:
            hid = self._host_victim(partition)
            if hid is None:
                return None
            self._evict_host(partition, hid)
        self._clock += 1
        hid = self._next_hid[partition]
        self._next_hid[partition] += 1
        self._host[partition][hid] = HostBlock(payload, owner, pinned,
                                               self._clock)
        return hid

    def host_get(self, partition: int, hid: int) -> HostBlock:
        return self._host[partition][hid]

    def host_pop(self, partition: int, hid: int):
        """Remove a host block (a restore is consuming it) and return its
        payload."""
        return self._host[partition].pop(hid).payload

    def touch(self, partition: int, hid: int) -> None:
        self._clock += 1
        self._host[partition][hid].last_used = self._clock

    def _host_victim(self, partition: int) -> Optional[int]:
        """LRU unpinned host block whose owner node (if any) can be dropped
        from the radix tree without orphaning children."""
        best, best_t = None, None
        for hid, hb in self._host[partition].items():
            if hb.pinned:
                continue
            if hb.owner is not None and hb.owner.children:
                continue  # interior node: dropping it would orphan the path
            if best_t is None or hb.last_used < best_t:
                best, best_t = hid, hb.last_used
        return best

    def _evict_host(self, partition: int, hid: int) -> None:
        hb = self._host[partition].pop(hid)
        self.host_evictions += 1
        if self.trace.enabled:
            self.trace.emit("host_evict", partition=partition)
        if hb.owner is not None and self.cache is not None:
            self.cache.drop_host_node(partition, hb.owner)
