"""Radix prefix cache: cross-request KV sharing over the paged block pool.

Real model-selection serving traffic is massively prefix-redundant — system
prompts, few-shot scaffolds, and eval templates repeat across requests (and
across the arches of a co-serving gang). This module lets a new request skip
recomputing any prefix an earlier request already pushed through the model:
completed requests *insert* their prompt blocks into a radix tree instead of
freeing them, and admission *matches* each incoming prompt against the tree,
seeding the request's block table with the shared blocks so chunked prefill
starts at the hit boundary (TTFT drops with hit length).

Structure
---------
One radix tree per pool **partition** (= per (trial, data-shard), matching
``BlockAllocator`` partitioning — block ids are partition-local, so a cached
block is only addressable by rows admitted into the same partition). Each
edge/node covers exactly one **block-aligned chunk** of ``block_size`` token
ids and owns the K/V written for exactly the token path root → node; causal
attention makes that K/V valid for *any* request whose prompt starts with
the same path.

Two-tier residency (serve/store.py): a node's K/V lives either in a device
pool block (``node.block`` >= 0) or, after being spilled under pool
pressure, in a host block of the tiered store (``node.block`` == -1,
``node.host`` set). Matching walks the tree regardless of residency;
*acquiring* a hit restores host-resident nodes — allocate a fresh device
block, enqueue an async swap-in on the transfer engine (flushed before the
slot's first compute call), move the payload out of the host tier — so a
spilled prefix still saves the prefill work, at the cost of a copy instead
of a recompute.

Sharing rules (the refcount/CoW invariants of serve/paging.py):

* the tree holds **one reference** per cached device-resident block; a radix
  hit adds one reference per matched block for the admitted request (dropped
  when its table closes), so a block's refcount is 1 + (live requests
  reading it). Host-resident nodes hold no device reference;
* full-block hits are read-only forever — the device scatter never writes
  below a row's ``kv_offset``;
* a **partial tail hit** (the request's prompt diverges inside a cached
  block) reuses the matched positions of that block but must write the rest:
  the engine forks it copy-on-write (``BlockTable.fork_shared`` + a transfer
  -engine pool copy) before the first write, so no block with refcount > 1
  is ever mutated;
* **reclamation** (:meth:`make_room`, reached via ``BlockStore.reclaim``)
  walks LRU *evictable* nodes — device-resident, refcount 1 (tree-only),
  with no device-resident children — and **spills** them to the host tier
  (extract payload, free the device block); only when the host tier is
  full or disabled does it fall back to destroying the node, the old
  single-tier behavior. Blocks referenced by live requests are pinned
  until completion either way.

Host-side only: matching, refcounts, and reclamation are plain Python; the
device interactions (CoW copies, swap-out extraction, swap-in injection)
all flow through ``serve.transfer.TransferEngine``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.obs.tracer import NULL_TRACER
from repro.serve.paging import BlockAllocator
from repro.serve.store import BlockStore


class RadixNode:
    """One cached block-aligned chunk: ``key`` is its token chunk, ``block``
    the partition-local device id holding its K/V (-1 while the chunk is
    spilled to the host tier, ``host`` then names the host block)."""

    __slots__ = ("key", "block", "host", "children", "parent", "last_used")

    def __init__(self, key: Tuple[int, ...], block: int,
                 parent: Optional["RadixNode"], last_used: int = 0):
        self.key = key
        self.block = block
        self.host: Optional[int] = None
        self.children: Dict[Tuple[int, ...], RadixNode] = {}
        self.parent = parent
        self.last_used = last_used


@dataclasses.dataclass
class PrefixHit:
    """Result of matching one prompt against one partition's radix tree.

    ``nodes`` is the chain of fully matched blocks (each ``block_size``
    tokens); ``tail``/``tail_tokens`` an optional partially matched block —
    its first ``tail_tokens`` positions carry valid K/V for this prompt and
    the engine must CoW-fork it before writing the rest. The hit is always
    capped below ``prompt_len`` so at least one prompt token remains to
    prefill (the head needs a final-position forward to emit token 0).

    Matched nodes may be host-resident (``block`` == -1); ``acquire``
    restores them and returns the *effective* hit whose ``block_ids`` are
    all device ids.
    """

    partition: int
    nodes: List[RadixNode]
    tail: Optional[RadixNode]
    tail_tokens: int
    block_size: int

    @property
    def hit_tokens(self) -> int:
        return len(self.nodes) * self.block_size + self.tail_tokens

    @property
    def n_full_blocks(self) -> int:
        return len(self.nodes)

    def _chain(self) -> List[RadixNode]:
        return self.nodes + ([self.tail] if self.tail is not None else [])

    @property
    def block_ids(self) -> List[int]:
        return [n.block for n in self._chain()]

    @property
    def device_ids(self) -> List[int]:
        """Device-resident matched ids (valid pre-acquire)."""
        return [n.block for n in self._chain() if n.block >= 0]

    @property
    def n_host_blocks(self) -> int:
        """Host-resident matched nodes — each restore will claim one fresh
        device block (admission charges them like new allocations)."""
        return sum(1 for n in self._chain() if n.block < 0)


class PrefixCache:
    """Per-partition radix trees over the tiered block store, with LRU
    spill-then-destroy reclamation of unreferenced nodes. See the module
    docstring for the sharing/residency rules; counters (hits, evictions,
    host_hit_tokens, ...) feed ``ServeStats``.

    Constructed over a :class:`~repro.serve.store.BlockStore` (a bare
    ``BlockAllocator`` is auto-wrapped in a host-tier-less store — the
    pre-tier API, identical destroy-on-evict semantics).
    """

    def __init__(self, store):
        if isinstance(store, BlockAllocator):
            store = BlockStore(store)
        self.store = store
        self.allocator = store.allocator
        store.cache = self  # the store's reclaim chokepoint walks this tree
        self._roots = [RadixNode((), -1, None)
                       for _ in range(self.allocator.n_partitions)]
        self._clock = 0  # deterministic LRU time (bumped per touch/insert)
        self.lookups = 0
        self.hits = 0  # matches with hit_tokens > 0 that were acquired
        self.hit_tokens = 0
        self.inserts = 0  # blocks adopted into the tree
        self.evictions = 0  # nodes destroyed (evicted from BOTH tiers)
        self.spills = 0  # nodes spilled device -> host (still matchable)
        self.host_hits = 0  # host-resident nodes restored by acquire()
        self.host_hit_tokens = 0  # hit tokens served via host restores
        self.trace = NULL_TRACER  # engine swaps in its tracer when tracing

    # -- queries -------------------------------------------------------------

    def _walk(self, partition: int):
        stack = [self._roots[partition]]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node.parent is not None:
                yield node

    def cached_blocks(self, partition: Optional[int] = None) -> int:
        """Device-resident blocks currently held by the tree (1 per
        device-resident node; spilled nodes hold host blocks instead)."""
        parts = (range(self.allocator.n_partitions) if partition is None
                 else [partition])
        return sum(1 for p in parts for n in self._walk(p) if n.block >= 0)

    def host_cached_blocks(self, partition: Optional[int] = None) -> int:
        """Host-resident (spilled) nodes still matchable in the tree."""
        parts = (range(self.allocator.n_partitions) if partition is None
                 else [partition])
        return sum(1 for p in parts for n in self._walk(p) if n.block < 0)

    # -- match / acquire -----------------------------------------------------

    def match(self, partition: int, prompt) -> PrefixHit:
        """Longest cached prefix of ``prompt`` in this partition's tree:
        a chain of full block-aligned chunks plus at most one partially
        matched tail block. Read-only (no refcounts change, no LRU touch) —
        admission may probe several partitions before committing to one via
        :meth:`acquire`. Host-resident nodes match like device ones."""
        bs = self.allocator.block_size
        plen = int(prompt.shape[0])
        self.lookups += 1
        node = self._roots[partition]
        nodes: List[RadixNode] = []
        i = 0
        while (i + 1) * bs <= plen:
            child = node.children.get(tuple(int(t) for t in
                                            prompt[i * bs:(i + 1) * bs]))
            if child is None:
                break
            nodes.append(child)
            node = child
            i += 1
        # leave at least one prompt token to prefill (head output = token 0)
        while nodes and len(nodes) * bs >= plen:
            nodes.pop()
        node = nodes[-1] if nodes else self._roots[partition]
        base = len(nodes) * bs
        rest = prompt[base:]
        # partial tail: longest common prefix with any child chunk, again
        # capped one short of the prompt end
        limit = min(int(rest.shape[0]) - 1, bs)
        tail, tail_tokens = None, 0
        for key, child in node.children.items():
            j = 0
            while j < limit and key[j] == int(rest[j]):
                j += 1
            if j > tail_tokens:
                tail, tail_tokens = child, j
        return PrefixHit(partition, nodes, tail, tail_tokens, bs)

    def acquire(self, hit: PrefixHit) -> PrefixHit:
        """Commit to a hit at admission: restore host-resident nodes to the
        device tier (fresh block + async swap-in, flushed before the slot's
        first compute call), add one reference per matched block (the
        request's table drops it on close), and refresh LRU stamps.

        Returns the *effective* hit — possibly truncated at the first node
        that could not be brought device-resident (restore allocation can
        fail under overcommit races, and a restore's own reclamation may
        destroy a deeper not-yet-referenced node of this very chain). The
        caller must seed/charge from the returned hit, not the matched one.
        Nodes are claimed in chain order, so reclamation can never evict an
        already-acquired link."""
        p = hit.partition
        self._clock += 1
        eff_nodes: List[RadixNode] = []
        truncated = False
        for node in hit.nodes:
            if not self._claim(p, node):
                truncated = True
                break
            eff_nodes.append(node)
        eff_tail, eff_tt = None, 0
        if not truncated and hit.tail is not None \
                and self._claim(p, hit.tail, tokens=hit.tail_tokens):
            eff_tail, eff_tt = hit.tail, hit.tail_tokens
        eff = PrefixHit(p, eff_nodes, eff_tail, eff_tt, hit.block_size)
        if eff.hit_tokens > 0:
            self.hits += 1
            self.hit_tokens += eff.hit_tokens
        return eff

    def _claim(self, partition: int, node: RadixNode,
               tokens: Optional[int] = None) -> bool:
        """Make one matched node device-resident and add the request's
        reference. False = the node is gone (destroyed since match) or the
        pool cannot back its restore right now."""
        if node.parent is None:  # destroyed by reclamation since match()
            return False
        if node.block < 0:
            if self.store.transfer is None:
                return False
            got = self.store.alloc(1, partition)  # may reclaim LRU others
            if got is None:
                return False
            payload = self.store.host_pop(partition, node.host)
            node.block, node.host = got[0], None
            self.store.transfer.swap_in(partition, node.block, payload)
            self.host_hits += 1
            self.host_hit_tokens += (self.allocator.block_size
                                     if tokens is None else tokens)
        self.allocator.incref([node.block], partition)
        node.last_used = self._clock
        return True

    # -- insert --------------------------------------------------------------

    def insert(self, partition: int, prompt, blocks: List[int]) -> int:
        """Adopt a completed request's *full* prompt blocks into the tree
        (called before its table closes, so every id in ``blocks`` is still
        live). Chunks already cached keep their existing node — the
        request's duplicate block simply drops with its table — except
        *host-resident* nodes, which are promoted back to the device tier by
        adopting the request's block (and freeing the stale host copy): the
        request just rewrote exactly this K/V on device, so the promotion
        saves a future swap-in for free. Returns the number of newly
        adopted blocks."""
        bs = self.allocator.block_size
        node = self._roots[partition]
        adopted = 0
        self._clock += 1
        for i in range(int(prompt.shape[0]) // bs):
            if i >= len(blocks):
                break
            key = tuple(int(t) for t in prompt[i * bs:(i + 1) * bs])
            child = node.children.get(key)
            if child is None:
                child = RadixNode(key, blocks[i], node, self._clock)
                node.children[key] = child
                self.allocator.incref([blocks[i]], partition)
                adopted += 1
            elif child.block < 0:
                self.store.host_pop(partition, child.host)  # drop stale copy
                child.block, child.host = blocks[i], None
                self.allocator.incref([blocks[i]], partition)
            child.last_used = self._clock
            node = child
        self.inserts += adopted
        return adopted

    # -- reclamation ---------------------------------------------------------

    def _evictable_leaves(self, partition: int) -> List[RadixNode]:
        """Device-resident nodes safe to spill/destroy: tree-only reference
        (refcount 1) and no device-resident children — a node whose children
        all live on the host may itself leave the device tier (its K/V is
        not an attention dependency of theirs; the path stays matchable)."""
        return [n for n in self._walk(partition)
                if n.block >= 0
                and all(c.block < 0 for c in n.children.values())
                and self.allocator.ref_count(n.block, partition) == 1]

    def make_room(self, partition: int, need: int) -> int:
        """Reclaim LRU unreferenced nodes until ``need`` device blocks are
        free in the partition (or nothing evictable remains): **spill** each
        victim to the host tier when it has room (the node stays matchable;
        an acquire swaps it back in), **destroy** it otherwise — the
        pre-tier behavior, now the last resort. Reclaiming a node may
        expose its parent as the next victim — cascades are rediscovered
        per round, which keeps the walk simple (trees are pool-bounded
        small). Called through ``BlockStore.reclaim`` (the single
        reclamation chokepoint). Returns the device blocks reclaimed."""
        reclaimed = 0
        while self.allocator.free_blocks(partition) < need:
            leaves = self._evictable_leaves(partition)
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.last_used)
            if not self._spill(partition, victim):
                self._drop(partition, victim)
            reclaimed += 1
        return reclaimed

    def _spill(self, partition: int, node: RadixNode) -> bool:
        """Move one unreferenced device-resident node to the host tier."""
        st = self.store
        if not st.spill or st.transfer is None \
                or not st.host_can_put(partition):
            return False
        payload = st.transfer.swap_out(partition, [node.block])[0]
        hid = st.host_put(partition, payload, owner=node)
        if hid is None:
            return False
        self.allocator.decref([node.block], partition)
        node.block, node.host = -1, hid
        self.spills += 1
        if self.trace.enabled:
            self.trace.emit("prefix_spill", partition=partition)
        return True

    def _drop(self, partition: int, node: RadixNode) -> None:
        """Destroy a device-resident node outright (no host room)."""
        del node.parent.children[node.key]
        node.parent = None
        self.allocator.decref([node.block], partition)
        self.evictions += 1
        if self.trace.enabled:
            self.trace.emit("prefix_evict", partition=partition, tier="device")

    def drop_host_node(self, partition: int, node: RadixNode) -> None:
        """Destroy a host-resident node whose host block was LRU-evicted
        under host-tier pressure (called back by the store; the host block
        itself is already gone)."""
        del node.parent.children[node.key]
        node.parent = None
        node.host = None
        self.evictions += 1
        if self.trace.enabled:
            self.trace.emit("prefix_evict", partition=partition, tier="host")
