"""Radix prefix cache: cross-request KV sharing over the paged block pool.

Real model-selection serving traffic is massively prefix-redundant — system
prompts, few-shot scaffolds, and eval templates repeat across requests (and
across the arches of a co-serving gang). This module lets a new request skip
recomputing any prefix an earlier request already pushed through the model:
completed requests *insert* their prompt blocks into a radix tree instead of
freeing them, and admission *matches* each incoming prompt against the tree,
seeding the request's block table with the shared blocks so chunked prefill
starts at the hit boundary (TTFT drops with hit length).

Structure
---------
One radix tree per pool **partition** (= per (trial, data-shard), matching
``BlockAllocator`` partitioning — block ids are partition-local, so a cached
block is only addressable by rows admitted into the same partition). Each
edge/node covers exactly one **block-aligned chunk** of ``block_size`` token
ids and owns one physical block whose K/V rows were written for exactly the
token path root → node; causal attention makes that K/V valid for *any*
request whose prompt starts with the same path.

Sharing rules (the refcount/CoW invariants of serve/paging.py):

* the tree holds **one reference** per cached block; a radix hit adds one
  reference per matched block for the admitted request (dropped when its
  table closes), so a block's refcount is 1 + (live requests reading it);
* full-block hits are read-only forever — the device scatter never writes
  below a row's ``kv_offset``;
* a **partial tail hit** (the request's prompt diverges inside a cached
  block) reuses the matched positions of that block but must write the rest:
  the engine forks it copy-on-write (``BlockTable.fork_shared`` + a device
  pool copy) before the first write, so no block with refcount > 1 is ever
  mutated;
* **eviction** reclaims LRU *leaves* whose block is referenced only by the
  tree (refcount 1) — interior nodes are path-pinned by their children and
  blocks referenced by live requests are pinned until completion. Eviction
  runs on demand when the free list cannot back an allocation
  (``BlockTable`` calls :meth:`make_room`).

Host-side only: matching, refcounts, and eviction are plain Python; the sole
device interaction is the CoW pool copy, compiled by
``core.pipeline.make_block_copy`` and issued by the engine.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.serve.paging import BlockAllocator


class RadixNode:
    """One cached block: ``key`` is its block-aligned token chunk, ``block``
    the partition-local physical id holding that chunk's K/V."""

    __slots__ = ("key", "block", "children", "parent", "last_used")

    def __init__(self, key: Tuple[int, ...], block: int,
                 parent: Optional["RadixNode"], last_used: int = 0):
        self.key = key
        self.block = block
        self.children: Dict[Tuple[int, ...], RadixNode] = {}
        self.parent = parent
        self.last_used = last_used


@dataclasses.dataclass
class PrefixHit:
    """Result of matching one prompt against one partition's radix tree.

    ``nodes`` is the chain of fully matched blocks (each ``block_size``
    tokens); ``tail``/``tail_tokens`` an optional partially matched block —
    its first ``tail_tokens`` positions carry valid K/V for this prompt and
    the engine must CoW-fork it before writing the rest. The hit is always
    capped below ``prompt_len`` so at least one prompt token remains to
    prefill (the head needs a final-position forward to emit token 0).
    """

    partition: int
    nodes: List[RadixNode]
    tail: Optional[RadixNode]
    tail_tokens: int
    block_size: int

    @property
    def hit_tokens(self) -> int:
        return len(self.nodes) * self.block_size + self.tail_tokens

    @property
    def n_full_blocks(self) -> int:
        return len(self.nodes)

    @property
    def block_ids(self) -> List[int]:
        ids = [n.block for n in self.nodes]
        if self.tail is not None:
            ids.append(self.tail.block)
        return ids


class PrefixCache:
    """Per-partition radix trees over the shared block pool, with LRU
    eviction of unreferenced leaves. See the module docstring for the
    sharing/eviction rules; counters (hits, evictions, ...) feed
    ``ServeStats``."""

    def __init__(self, allocator: BlockAllocator):
        self.allocator = allocator
        self._roots = [RadixNode((), -1, None)
                       for _ in range(allocator.n_partitions)]
        self._clock = 0  # deterministic LRU time (bumped per touch/insert)
        self.lookups = 0
        self.hits = 0  # matches with hit_tokens > 0 that were acquired
        self.hit_tokens = 0
        self.inserts = 0  # blocks adopted into the tree
        self.evictions = 0  # blocks reclaimed by LRU eviction

    # -- queries -------------------------------------------------------------

    def cached_blocks(self, partition: Optional[int] = None) -> int:
        """Blocks currently held by the tree (1 per node)."""
        parts = (range(self.allocator.n_partitions) if partition is None
                 else [partition])
        total = 0
        for p in parts:
            stack = [self._roots[p]]
            while stack:
                node = stack.pop()
                stack.extend(node.children.values())
                total += node is not self._roots[p]
        return total

    # -- match / acquire -----------------------------------------------------

    def match(self, partition: int, prompt) -> PrefixHit:
        """Longest cached prefix of ``prompt`` in this partition's tree:
        a chain of full block-aligned chunks plus at most one partially
        matched tail block. Read-only (no refcounts change, no LRU touch) —
        admission may probe several partitions before committing to one via
        :meth:`acquire`."""
        bs = self.allocator.block_size
        plen = int(prompt.shape[0])
        self.lookups += 1
        node = self._roots[partition]
        nodes: List[RadixNode] = []
        i = 0
        while (i + 1) * bs <= plen:
            child = node.children.get(tuple(int(t) for t in
                                            prompt[i * bs:(i + 1) * bs]))
            if child is None:
                break
            nodes.append(child)
            node = child
            i += 1
        # leave at least one prompt token to prefill (head output = token 0)
        while nodes and len(nodes) * bs >= plen:
            nodes.pop()
        node = nodes[-1] if nodes else self._roots[partition]
        base = len(nodes) * bs
        rest = prompt[base:]
        # partial tail: longest common prefix with any child chunk, again
        # capped one short of the prompt end
        limit = min(int(rest.shape[0]) - 1, bs)
        tail, tail_tokens = None, 0
        for key, child in node.children.items():
            j = 0
            while j < limit and key[j] == int(rest[j]):
                j += 1
            if j > tail_tokens:
                tail, tail_tokens = child, j
        return PrefixHit(partition, nodes, tail, tail_tokens, bs)

    def acquire(self, hit: PrefixHit) -> None:
        """Commit to a hit at admission: add one reference per matched block
        (the request's table drops it on close) and refresh LRU stamps."""
        ids = hit.block_ids
        if not ids:
            return
        self.allocator.incref(ids, hit.partition)
        self.hits += 1
        self.hit_tokens += hit.hit_tokens
        self._clock += 1
        for n in hit.nodes:
            n.last_used = self._clock
        if hit.tail is not None:
            hit.tail.last_used = self._clock

    # -- insert --------------------------------------------------------------

    def insert(self, partition: int, prompt, blocks: List[int]) -> int:
        """Adopt a completed request's *full* prompt blocks into the tree
        (called before its table closes, so every id in ``blocks`` is still
        live). Chunks already cached keep their existing node — the
        request's duplicate block simply drops with its table. Returns the
        number of newly adopted blocks."""
        bs = self.allocator.block_size
        node = self._roots[partition]
        adopted = 0
        self._clock += 1
        for i in range(int(prompt.shape[0]) // bs):
            if i >= len(blocks):
                break
            key = tuple(int(t) for t in prompt[i * bs:(i + 1) * bs])
            child = node.children.get(key)
            if child is None:
                child = RadixNode(key, blocks[i], node, self._clock)
                node.children[key] = child
                self.allocator.incref([blocks[i]], partition)
                adopted += 1
            child.last_used = self._clock
            node = child
        self.inserts += adopted
        return adopted

    # -- eviction ------------------------------------------------------------

    def _evictable_leaves(self, partition: int) -> List[RadixNode]:
        out = []
        stack = [self._roots[partition]]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if (node.parent is not None and not node.children
                    and self.allocator.ref_count(node.block, partition) == 1):
                out.append(node)
        return out

    def make_room(self, partition: int, need: int) -> int:
        """Evict LRU unreferenced leaves until ``need`` blocks are free in
        the partition (or nothing evictable remains). Evicting a leaf may
        expose its parent as the next victim — cascades are rediscovered per
        round, which keeps the walk simple (trees are pool-bounded small).
        Returns the number of blocks reclaimed."""
        evicted = 0
        while self.allocator.free_blocks(partition) < need:
            leaves = self._evictable_leaves(partition)
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.last_used)
            del victim.parent.children[victim.key]
            victim.parent = None
            self.allocator.decref([victim.block], partition)
            evicted += 1
        self.evictions += evicted
        return evicted
