"""Continuous-batching serve engine over the Hydra pipeline.

The static path in ``launch/serve.py --static`` admits one fixed batch, runs
prefill once, and decodes in lockstep — when a request finishes early its
pipeline slot idles until the whole batch drains, the exact "idle slots"
pathology the paper's shard parallelism exists to kill. This engine applies
the same slot-filling insight to a *dynamic* request stream — and, like the
paper's gangs, to a dynamic stream addressed to *several model variants at
once*: the slot grid is (trial k, microbatch m, batch-row b), trial row k
holds variant k's weights, and the batcher routes each request's arch id to
its own trial rows, so one gang-scheduled SPMD program co-serves K
architectures (the serving analogue of Hydra/Saturn gang planning).

Cell lifecycle (one cell = one (k, m, b) position of the pipelined serve
step, owning one KV/SSM-cache row of trial k; requests with ``arch == k``
are the only ones that ever occupy it):

  FREE ──admit──► PREFILL ──last chunk──► DECODE ──budget hit──► FREE
   ▲   (arch k's queue head moves into a       (one token per engine round │
   │    free (k, m, b) cell; cache row          via the masked decode      │
   │    zeroed — KV rows beyond kv_len are      step; per-row positions;   │
   │    never attended, but SSM states are      every trial row decodes in │
   │    recurrent and must restart from zero)   the same pipeline call)    │
   └──────────────────────────────────────────────────────────────────────┘

Paged mode (``eng.paged``) replaces the per-cell dense cache strips with one
block pool per (trial, layer) (``serve/paging.py``) — the pool leaf carries a
leading K axis, so each variant's blocks are physically its own slice and the
allocator is partitioned per (trial, data-shard). The cache column of the
lifecycle becomes block-table bookkeeping:

  FREE ──admit──► PREFILL ──last chunk──► DECODE ──budget hit──► FREE
   ▲   (admission defers — per-arch           (crossing a block boundary  │
   │    backpressure, other arches keep        allocs one block:          │
   │    flowing — until the request's exact    alloc-on-append)           │
   │    block commitment fits trial k's                                   │
   │    partition; each prefill chunk grows                               │
   │    the cell's block table; no cache                                  │
   │    zeroing — stale blocks are masked                                 │
   │    by kv_len)                                                        │
   └────────────── blocks returned to the allocator's free list ──────────┘

Short requests then stop reserving ``max_seq``-worst-case HBM, so
``plan_serve_capacity(paged=True)`` packs strictly more concurrent cells
into the same budget (admission by *expected* length against the pool; a
traffic ``mix`` sizes the grid for K arches' expected lengths and arrival
weights at once).

Prefix caching (``prefix_cache=True``, paged only) adds cross-request KV
sharing on top: completed requests insert their prompt blocks into a radix
tree (``serve/prefix_cache.py``) instead of dropping them, admission matches
each prompt against the tree and seeds the slot from the cached block table
at ``pos`` = hit length (chunked prefill starts at the hit boundary — whole
prefill waves are skipped, so TTFT drops with hit length), and a write into
a partially-matched shared tail block first forks it copy-on-write via the
transfer engine (``serve/transfer.py`` — CoW copies, device→host spills and
host→device restores all batch into one flush per round) — greedy tokens
stay bit-identical with the cache on or off. Under pool pressure,
unreferenced cached leaves are spilled to the :class:`BlockStore` host tier
(still matchable; admission hits trigger an async swap-in) or destroyed LRU
when no host room remains, so the cache never deadlocks admission. Past
``overcommit`` 1.0 the engine *retracts* the youngest-admitted running
request on exhaustion — its generated tokens are swapped to host (or
replayed teacher-forced) and the request re-enters its queue head —
instead of relying on the stall-retry guard.

* **Admission / chunked prefill.** A prompt is split into
  ``EngineConfig.prefill_chunks`` near-equal chunks; each engine round
  advances every prefilling cell by one chunk via the ``append`` serve step
  (per-row kv offsets — cells in the same call may sit at different depths,
  and cells of *different trial rows* ride in the same call: the step
  indexes params, caches, and block tables by each cell's k). Calls are
  grouped by chunk length so token shapes stay static; the final chunk's
  head output is the request's first generated token. Admission order
  within an arch follows the batcher ``policy`` (fcfs / sjf / deadline).
* **Recycling.** The round a request exhausts its budget, its cell is
  released and the cache row is zeroed (``make_slot_reset``); the next
  queued request of that arch is admitted the same round. Slots therefore
  never idle while their arch's queue is non-empty — steady-state occupancy
  stays ~1 where the static path decays as a batch drains.
* **Sliding window.** ``eng.window`` > 0 (attention-only archs) bounds every
  query to the trailing window: the cache keeps its absolute ``max_seq``
  layout and the append/decode steps mask positions ≤ pos − window, so
  greedy tokens match a windowed single-device oracle exactly.
* **Exactness.** Every active row always processes exactly its own real
  tokens at its own positions against its own trial's weights, so greedy
  tokens match serving that row's arch alone through a single-arch engine
  (and the single-device oracle) per request, bit-for-bit.

Per-request completion is exposed as :class:`repro.serve.request.Completion`
records (with TTFT/TPOT tick latencies) instead of lockstep tensors.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import pipeline as pl
from repro.models.layers import ModelOptions
from repro.obs.metrics import MetricRegistry
from repro.obs.tracer import resolve
from repro.serve.batcher import Batcher, ResumeState
from repro.serve.paging import BlockAllocator, blocks_for
from repro.serve.prefix_cache import PrefixCache
from repro.serve.request import Completion, Request
from repro.serve.store import BlockStore
from repro.serve.transfer import TransferEngine


def _pctl(samples, q) -> float:
    return float(np.percentile(np.asarray(samples, np.float64), q))


# ServeStats' numeric fields, now typed metrics in a MetricRegistry (the
# attribute name IS the metric name, so exports need no mapping table)
_COUNTER_FIELDS = (
    "ticks", "calls", "prefill_calls", "mixed_calls", "prefill_slot_ticks",
    "tokens_generated", "prompt_tokens", "pool_stalls", "prefix_hits",
    "prefix_hit_tokens", "prefix_inserts", "prefix_evictions",
    "prefix_spills", "host_hit_tokens", "cow_forks", "retractions",
    "restored", "swap_out_blocks", "swap_in_blocks")
_GAUGE_FIELDS = ("wall_s", "peak_live")
_HIST_FIELDS = (
    "occupancy_samples", "decode_busy_samples", "mixed_fill_samples",
    "block_usage_samples", "ttft_samples", "tpot_samples")
_ROUTED = frozenset(_COUNTER_FIELDS + _GAUGE_FIELDS + _HIST_FIELDS)


class ServeStats:
    """Scheduling/throughput counters for one engine run.

    A facade over :class:`repro.obs.metrics.MetricRegistry`: counters and
    gauges keep their legacy attribute interface (``stats.calls += 1``,
    ``stats.wall_s = ...``) by routing reads/writes through the registry,
    and the former unbounded ``*_samples`` lists are bounded
    :class:`~repro.obs.metrics.Reservoir` histograms that still support
    ``append``/``len``/``max``/``np.mean``. ``summary()`` keeps its exact
    historical key set (plus additive p99s), so bench gates and tests see
    the same shape.

    Counter semantics (unchanged):

    * ``prefill_calls`` — append-mode pipeline calls (prefill waves);
      ``mixed_calls`` — fused mixed-tick calls (prefill + decode).
    * ``prefill_slot_ticks`` — (cell, round) pairs spent prefilling — the
      per-request prefill-tick total (calls group concurrent cells, so this
      is the measure a prefix-cache hit actually shrinks).
    * ``peak_live`` — max concurrently admitted requests (capacity used);
      ``pool_stalls`` — paged row-rounds deferred on an exhausted pool.
    * prefix cache: ``prefix_hits`` (admitted requests with a non-empty
      hit), ``prefix_hit_tokens``, ``prefix_inserts`` (blocks adopted),
      ``prefix_evictions`` (nodes destroyed — gone from BOTH tiers),
      ``prefix_spills`` (nodes spilled device → host, still matchable),
      ``host_hit_tokens`` (hit tokens served via host restores),
      ``cow_forks`` (shared tail blocks forked copy-on-write).
    * tiered store: ``retractions`` (running requests preempted under
      overcommit > 1), ``restored`` (retracted requests re-admitted),
      ``swap_out_blocks`` / ``swap_in_blocks`` (payloads device ↔ host).
    """

    def __init__(self, prefix_enabled: bool = False,
                 registry: Optional[MetricRegistry] = None):
        # bypass __setattr__ for the plain attributes (the registry most of
        # all — routing consults it)
        object.__setattr__(self, "registry",
                           registry if registry is not None
                           else MetricRegistry())
        object.__setattr__(self, "prefix_enabled", bool(prefix_enabled))
        object.__setattr__(self, "tokens_per_arch", {})
        for n in _COUNTER_FIELDS:
            self.registry.counter(n)
        self.registry.gauge("wall_s", 0.0)
        self.registry.gauge("peak_live", 0)
        for n in _HIST_FIELDS:
            self.registry.histogram(n)

    def __getattr__(self, name):
        # normal lookup failed: metric fields live in the registry
        try:
            reg = object.__getattribute__(self, "registry")
            return reg.value(name)
        except KeyError:
            raise AttributeError(name) from None

    def __setattr__(self, name, value):
        if name in _ROUTED:
            self.registry.set_value(name, value)  # TypeError on histograms
        else:
            object.__setattr__(self, name, value)

    @property
    def slot_occupancy(self) -> float:
        """Mean fraction of slot cells holding a live request, sampled once
        per engine round — the paper's utilization story applied to serving."""
        s = self.occupancy_samples
        return s.mean_value if s else 0.0

    @property
    def decode_occupancy(self) -> float:
        """Mean busy fraction of the decode step's rows."""
        s = self.decode_busy_samples
        return s.mean_value if s else 0.0

    @property
    def mixed_fill_ratio(self) -> float:
        """Mean fraction of the mixed wave's padded (cell, qmax) token grid
        carrying real tokens — how much of each fused call is useful work."""
        s = self.mixed_fill_samples
        return s.mean_value if s else 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_generated / self.wall_s if self.wall_s > 0 else 0.0

    def record_completion(self, comp: Completion) -> None:
        self.ttft_samples.append(comp.ttft_ticks)
        if len(comp.tokens) > 1:
            self.tpot_samples.append(comp.tpot_ticks)
        self.tokens_per_arch[comp.arch] = (
            self.tokens_per_arch.get(comp.arch, 0) + len(comp.tokens))

    def snapshot(self) -> dict:
        """Every metric (counters/gauges as numbers, histograms summarized)
        for the metrics exporter; ``summary()`` stays the human/bench view."""
        out = self.registry.snapshot()
        if len(self.tokens_per_arch) > 1:
            for k in sorted(self.tokens_per_arch):
                out[f"tokens_arch{k}"] = self.tokens_per_arch[k]
        return out

    def summary(self) -> dict:
        out = {"ticks": self.ticks, "calls": self.calls,
               "prefill_calls": self.prefill_calls,
               "prefill_slot_ticks": self.prefill_slot_ticks,
               "tokens_generated": self.tokens_generated,
               "prompt_tokens": self.prompt_tokens,
               "peak_live": self.peak_live,
               "slot_occupancy": round(self.slot_occupancy, 4),
               "decode_occupancy": round(self.decode_occupancy, 4),
               "wall_s": round(self.wall_s, 4),
               "tokens_per_s": round(self.tokens_per_s, 2)}
        if self.mixed_calls:
            out["mixed_calls"] = self.mixed_calls
            out["mixed_fill_ratio"] = round(self.mixed_fill_ratio, 4)
        if self.ttft_samples:
            out["ttft_p50"] = round(_pctl(self.ttft_samples, 50), 2)
            out["ttft_p95"] = round(_pctl(self.ttft_samples, 95), 2)
            out["ttft_p99"] = round(_pctl(self.ttft_samples, 99), 2)
        if self.tpot_samples:
            out["tpot_p50"] = round(_pctl(self.tpot_samples, 50), 2)
            out["tpot_p95"] = round(_pctl(self.tpot_samples, 95), 2)
            out["tpot_p99"] = round(_pctl(self.tpot_samples, 99), 2)
        if len(self.tokens_per_arch) > 1:
            out["tokens_per_arch"] = {
                k: self.tokens_per_arch[k]
                for k in sorted(self.tokens_per_arch)}
        if self.block_usage_samples:
            out["peak_blocks_in_use"] = int(
                self.block_usage_samples.max_value)
            out["pool_stalls"] = self.pool_stalls
            out["retractions"] = self.retractions
            out["restored"] = self.restored
            out["swap_out_blocks"] = self.swap_out_blocks
            out["swap_in_blocks"] = self.swap_in_blocks
        if self.prefix_enabled:
            out["prefix_hits"] = self.prefix_hits
            out["prefix_hit_tokens"] = self.prefix_hit_tokens
            out["host_hit_tokens"] = self.host_hit_tokens
            out["prefix_inserts"] = self.prefix_inserts
            out["prefix_evictions"] = self.prefix_evictions
            out["prefix_spills"] = self.prefix_spills
            out["cow_forks"] = self.cow_forks
        return out


@dataclasses.dataclass
class SpecStats:
    """Gang-speculation counters: drafter proposals vs target verification.

    The headline metric is target-row ticks per output token — with
    speculation the target only runs prefill calls and verify calls
    (drafter rows absorb the autoregressive ticks), so
    ``(prefill_calls + verify_calls) / tokens_generated`` drops below the
    target-only engine's ``calls / tokens_generated`` whenever acceptance
    is non-trivial. Tokens are bit-identical by construction either way.
    """

    proposed: int = 0  # drafter tokens offered to verify calls
    accepted: int = 0  # proposals matching the target's own greedy argmax
    bonus: int = 0  # free target tokens (one per verified row: position
    # n_acc is the target's own argmax, correct even on full rejection)
    draft_calls: int = 0  # drafter-row pipeline calls (catch-up + propose)
    verify_calls: int = 0  # target verify calls (one per spec round)
    rollback_blocks: int = 0  # pool blocks freed by partial-row truncation

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / self.proposed if self.proposed else 0.0

    def summary(self) -> dict:
        return {"spec_proposed": self.proposed,
                "spec_accepted": self.accepted,
                "spec_bonus_tokens": self.bonus,
                "spec_draft_calls": self.draft_calls,
                "spec_verify_calls": self.verify_calls,
                "spec_rollback_blocks": self.rollback_blocks,
                "acceptance_rate": round(self.acceptance_rate, 4)}


class ServeEngine:
    """Continuous-batching engine: per-arch request queues → (k, m, b) cells.

    Parameters mirror the static path: ``eng.n_trials`` trial rows (one per
    co-served model variant — ``params`` carries each variant's weights on
    its leading K axis) × ``eng.n_microbatches`` × global microbatch rows
    define the slot grid, ``eng.max_seq`` bounds each cache row,
    ``eng.prefill_chunks`` sets the admission chunk count. ``eng`` is
    normalized to spatial-chunking off (the engine chunks *temporally*,
    across calls, so every microbatch slot owns one cache group).
    ``policy`` picks the per-arch admission order (fcfs / sjf / deadline).
    ``fused`` folds each round's prefill waves and decode step into ONE
    mixed-tick pipeline call (per-row ragged qlens); greedy tokens stay
    bit-identical to the split schedule always, and per-request tick
    latencies too on preemption-free schedules (under overcommit
    retraction the atomic fused round preempts a wave row *before* its
    chunk runs, where split preempts after — timing may interleave
    differently, tokens never change).
    """

    def __init__(self, cfg: ArchConfig, eng: pl.EngineConfig, mesh, params,
                 opts: Optional[ModelOptions] = None,
                 overcommit: float = 1.0, policy: str = "fcfs",
                 prefix_cache: bool = False,
                 host_blocks: Optional[int] = None, spill: bool = True,
                 fused: bool = False, spec_gamma: int = 0,
                 spec_pairs: Optional[dict] = None, tracer=None):
        if cfg.rope == "mrope" or cfg.frontend is not None:
            raise ValueError("continuous batching supports text-only archs; "
                             "use the static path for mrope/frontend models")
        if eng.window and (cfg.family in ("ssm", "hybrid")
                           or cfg.hybrid is not None):
            raise ValueError(
                "sliding-window continuous serving supports attention-only "
                "archs (SSM state is not positional; the hybrid shared cache "
                "is a window-sized ring the append step cannot address)")
        self.cfg = cfg
        self.opts = opts or ModelOptions()
        # NULL_TRACER when off: emission sites guard with `if tr.enabled:`
        # and build no event dicts on the disabled path
        self.trace = resolve(tracer)
        _tr = self.trace if self.trace.enabled else None
        self._round_modes: list = []
        self._retracted: set = set()  # rids retracted at least once (tracing)
        self.eng = dataclasses.replace(eng, prefill_chunks=1)
        self.n_arches = self.eng.n_trials
        self.n_chunks = max(1, eng.prefill_chunks)
        self.mesh = mesh
        self.params = params
        self.mb_global = self.eng.microbatch * (
            1 if self.eng.batch_replicated
            else self.eng.data_size * self.eng.pod_size)
        self.decode_step = pl.make_serve_step(
            cfg, self.opts, self.eng, mesh, "decode", with_active=True,
            tracer=_tr)
        self.append_step = pl.make_serve_step(
            cfg, self.opts, self.eng, mesh, "append", with_active=True,
            tracer=_tr)
        self.fused = bool(fused)
        self.mixed_step = None
        if self.fused:
            if cfg.family in ("ssm", "hybrid") or cfg.hybrid is not None:
                raise ValueError(
                    "fused mixed-tick admission is attention-family only "
                    "(ragged waves pad rows to the wave max and a recurrent "
                    "state would advance through the padded positions)")
            self.mixed_step = pl.make_serve_step(
                cfg, self.opts, self.eng, mesh, "mixed", with_active=True,
                tracer=_tr)
        # -- gang speculation: pair each target trial row with a drafter row
        self.spec_gamma = int(spec_gamma)
        self.spec_pairs: dict = {}
        self.verify_step = None
        self.spec_stats = SpecStats()
        if self.spec_gamma > 0:
            if self.spec_gamma < 1:
                raise ValueError(f"spec_gamma must be >= 1, got {spec_gamma}")
            if self.fused:
                raise ValueError(
                    "gang speculation and fused mixed-tick admission both "
                    "own the round's ragged call structure; enable one")
            if cfg.family in ("ssm", "hybrid") or cfg.hybrid is not None:
                raise ValueError(
                    "gang speculation is attention-family only (rollback "
                    "truncates KV positionally; recurrent state cannot be "
                    "rewound to an earlier position)")
            if spec_pairs is None:
                if self.n_arches % 2:
                    raise ValueError(
                        f"default drafter pairing needs an even n_trials "
                        f"(targets 0..K/2-1 draft on K/2..K-1), got "
                        f"{self.n_arches}; pass spec_pairs explicitly")
                half = self.n_arches // 2
                spec_pairs = {k: half + k for k in range(half)}
            tgt, drf = set(spec_pairs), set(spec_pairs.values())
            if len(drf) != len(spec_pairs) or (tgt & drf) or not all(
                    0 <= k < self.n_arches for k in tgt | drf):
                raise ValueError(
                    f"spec_pairs must map disjoint target rows to distinct "
                    f"drafter rows, all within n_trials={self.n_arches}: "
                    f"got {spec_pairs}")
            self.spec_pairs = dict(spec_pairs)
            self.verify_step = pl.make_serve_step(
                cfg, self.opts, self.eng, mesh, "verify", with_active=True,
                tracer=_tr)
        self.paged = bool(self.eng.paged)
        if self.opts.use_paged_kernel and not self.paged:
            raise ValueError("use_paged_kernel attends through block tables; "
                             "enable eng.paged")
        self.allocator = None
        self.store = None
        self.transfer = None
        if prefix_cache and not self.paged:
            raise ValueError("the radix prefix cache shares paged KV blocks; "
                             "enable eng.paged to use prefix_cache")
        if overcommit > 1.0 and not self.paged:
            raise ValueError("overcommit > 1.0 preempts paged block "
                             "commitments; dense strips cannot be retracted "
                             "— enable eng.paged")
        if self.paged:
            # one pool partition per (trial, data/pod shard): each variant's
            # pool leaf slice is its own, and rows allocate only from the
            # partition their (k, shard) owns (tables carry local ids)
            n_parts = (1 if self.eng.batch_replicated
                       else self.eng.data_size * self.eng.pod_size)
            self.allocator = BlockAllocator(
                self.eng.n_blocks * self.n_arches, self.eng.block_size,
                n_partitions=self.n_arches * n_parts)
            self.max_blocks = blocks_for(self.eng.max_seq,
                                         self.eng.block_size)
            # no slot reset: paged serving is attention-only (no recurrent
            # state) and stale pool blocks are masked via kv_len
            self.reset_fn = None
            # every block movement — CoW copies, swap-out, swap-in — flows
            # through the transfer engine, batched into one flush per round
            self.transfer = TransferEngine(
                self.n_arches, n_parts,
                kernels=pl.make_transfer_kernels(cfg, self.eng, mesh))
            self.transfer.bind(lambda: self.cache, self._set_cache)
            hb = self.eng.host_blocks if host_blocks is None else host_blocks
            self.store = BlockStore(self.allocator, host_blocks=hb,
                                    spill=spill, transfer=self.transfer)
            self.store.trace = self.trace
        else:
            self.reset_fn = pl.make_slot_reset(cfg, self.eng, mesh)
        self.prefix_cache = None
        if prefix_cache:
            self.prefix_cache = PrefixCache(self.store)
            self.prefix_cache.trace = self.trace
        self.cache = pl.serve_cache_struct(cfg, self.eng, dry_run=False)
        self.batcher = Batcher(self.eng.n_microbatches, self.mb_global,
                               self.n_chunks, self.eng.max_seq,
                               n_trials=self.n_arches,
                               allocator=self.allocator,
                               rows_per_partition=self.eng.microbatch,
                               overcommit=overcommit, policy=policy,
                               prefix_cache=self.prefix_cache,
                               store=self.store, transfer=self.transfer,
                               spec_pairs=self.spec_pairs,
                               tracer=self.trace)
        # preemption replaces the stall-retry deadlock guard past 1.0
        self.retractable = self.paged and overcommit > 1.0
        self.tick = 0
        self._stalled_ticks = 0
        self.stats = ServeStats(prefix_enabled=prefix_cache)
        self.completions: list = []

    def _set_cache(self, cache) -> None:
        self.cache = cache

    # -- public API ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.batcher.enqueue(req)

    def done(self) -> bool:
        return self.batcher.idle()

    def run(self, requests=None, max_ticks: int = 100_000) -> list:
        """Drive the engine until every submitted request completes."""
        for r in requests or []:
            self.submit(r)
        t0 = time.monotonic()
        while not self.done():
            if self.tick >= max_ticks:
                raise RuntimeError(f"engine did not drain in {max_ticks} "
                                   f"ticks ({self.batcher.occupied()} live)")
            self.step()
        self.stats.wall_s += time.monotonic() - t0
        return sorted(self.completions, key=lambda c: c.rid)

    # -- one scheduling round ------------------------------------------------

    def step(self) -> bool:
        """Admit → prefill wave → decode. Returns False when fully drained."""
        if self.done():
            return False
        self.tick += 1
        self.stats.ticks += 1
        tr = self.trace
        if tr.enabled:
            tr.begin_tick(self.tick)
            self._round_modes = []
        calls_before = self.stats.calls
        admitted = self.batcher.admit(self.tick)
        if admitted:
            if not self.paged:
                self._reset_rows(admitted)
            self.stats.prompt_tokens += sum(
                s.request.prompt_len for s in admitted if not s.resumed)
            if tr.enabled:
                for s in admitted:
                    rid = s.request.rid
                    if rid in self._retracted:
                        via = ("recompute" if s.resume_tokens
                               else "swap" if s.resumed else "requeue")
                        tr.req("restore", rid, k=s.k, m=s.m, b=s.b, via=via)
                        self._retracted.discard(rid)
                    else:
                        tr.req("admit", rid, k=s.k, m=s.m, b=s.b,
                               plen=s.request.prompt_len)
                    if s.hit_tokens:
                        tr.req("prefix_hit", rid, tokens=s.hit_tokens)
        occupied = self.batcher.occupied()
        self.stats.peak_live = max(self.stats.peak_live, occupied)
        self.stats.occupancy_samples.append(occupied / self.batcher.n_cells)
        if self.allocator is not None:
            self.stats.block_usage_samples.append(
                self.allocator.used_blocks())
        if self.fused:
            self._mixed_call()
        else:
            for qlen, slots in sorted(self.batcher.prefill_groups().items()):
                self._prefill_call(qlen, slots)
            dec = self.batcher.decode_slots()
            if self.spec_pairs:
                plain = [s for s in dec if s.peer is None]
                if plain:
                    self._decode_call(plain)
                paired = [s for s in dec if s.peer is not None]
                if paired:
                    self._spec_round(paired)
            elif dec:
                self._decode_call(dec)
        # belt-and-braces: nothing stays in flight across rounds (admission
        # swap-ins with no same-round compute call, e.g.)
        if self.transfer is not None and self.transfer.pending():
            self.transfer.flush()
        # a pool can still wedge (e.g. overcommit 1.0 with every live row at
        # a block boundary, or retraction finding only in-flight victims);
        # flag the deadlock instead of spinning to max_ticks
        if occupied and self.stats.calls == calls_before and not admitted:
            self._stalled_ticks += 1
            if self._stalled_ticks > 100:
                raise RuntimeError(
                    "engine stalled: block pool exhausted with every live "
                    "row waiting for a block (raise overcommit above 1.0 to "
                    "enable retraction, grow n_blocks, or grow host_blocks)")
        else:
            self._stalled_ticks = 0
        if self.transfer is not None:
            self.stats.cow_forks = self.transfer.cow_copies
            self.stats.swap_out_blocks = self.transfer.swap_out_blocks
            self.stats.swap_in_blocks = self.transfer.swap_in_blocks
            self.stats.restored = self.batcher.restored
        if self.prefix_cache is not None:
            # synced at end of round so this tick's completions (inserts)
            # and allocation-pressure evictions are already counted
            self.stats.prefix_hits = self.prefix_cache.hits
            self.stats.prefix_hit_tokens = self.prefix_cache.hit_tokens
            self.stats.host_hit_tokens = self.prefix_cache.host_hit_tokens
            self.stats.prefix_inserts = self.prefix_cache.inserts
            self.stats.prefix_evictions = self.prefix_cache.evictions
            self.stats.prefix_spills = self.prefix_cache.spills
        if tr.enabled:
            rec = {"modes": self._round_modes, "occupied": occupied,
                   "occupancy": round(occupied / self.batcher.n_cells, 4),
                   "queues": [len(q) for q in self.batcher.queues]}
            if self.allocator is not None:
                rec["pool_blocks"] = self.allocator.used_blocks()
                rec["host_depth"] = [
                    self.store.host_used(p)
                    for p in range(self.store.n_partitions)]
                rec["inflight"] = self.transfer.take_round_peak()
            tr.round(**rec)
        return True

    # -- internals -----------------------------------------------------------

    def _grid(self, qlen: int):
        k, m, b = self.n_arches, self.eng.n_microbatches, self.mb_global
        return (np.zeros((k, m, b, qlen), np.int32),
                np.zeros((k, m, b), np.int32),
                np.zeros((k, m, b), bool))

    def _reset_rows(self, slots) -> None:
        mask = np.zeros((self.n_arches, self.eng.n_microbatches,
                         self.mb_global), bool)
        for s in slots:
            mask[s.k, s.m, s.b] = True
            if s.peer is not None:  # the drafter mirror cell starts cold too
                mask[s.peer.k, s.peer.m, s.peer.b] = True
        self.cache = self.reset_fn(self.cache, jnp.asarray(mask))

    def _block_tables(self, slots):
        """(K, M, mb_global, width) int32 local ids; rows not in the call
        stay -1 (their writes are dropped device-side anyway).

        Under ``use_paged_kernel`` the width is trimmed to the power-of-two
        bucket covering the longest live table instead of the provisioned
        ``max_blocks`` — the kernel path's per-call work then scales with
        live length, not max_seq (the gather path always pays full width).
        Bucketing bounds step recompiles to log2(max_blocks) shapes."""
        width = self.max_blocks
        if self.opts.use_paged_kernel:
            live = max((len(s.table.blocks) for s in slots), default=1)
            width = 1
            while width < max(live, 1):
                width *= 2
            width = min(width, self.max_blocks)
        bt = np.full((self.n_arches, self.eng.n_microbatches, self.mb_global,
                      width), -1, np.int32)
        for s in slots:
            bt[s.k, s.m, s.b] = s.table.as_row(width)
        return bt

    def _prepare(self, slots, extra) -> list:
        """Make each slot writable for its next ``extra`` positions: grow its
        block table (retracting a victim under overcommit if the pool is
        dry), then enqueue CoW forks for shared write-range blocks. Rows the
        pool still cannot back are stalled (kept out of this round's call,
        retried next round)."""
        if not self.paged:
            return list(slots)
        ready = []
        for s in slots:
            if s.request is None:
                continue  # retracted earlier this round by another row
            if self._ensure(s, extra):
                ready.append(s)
            elif s.request is not None:
                self.stats.pool_stalls += 1
        # a later row's retraction may have victimized an already-ready one
        ready = [s for s in ready if s.request is not None]
        return self._cow_forks(ready, extra)

    def _ensure(self, slot, extra) -> bool:
        if slot.table.ensure(slot.pos + extra):
            return True
        if not self.retractable:
            return False
        return self._retract_for(slot, extra)

    def _retract_for(self, slot, extra) -> bool:
        """Free pool room for ``slot`` by preempting the lowest-priority
        running request in its partition (youngest admission tick, ties by
        rid — SGLang-style). The requester itself is fair game: if it IS the
        youngest, it gets retracted and the round moves on. Victims with
        in-flight transfer blocks are skipped (their bytes are not yet
        addressable)."""
        p = self.batcher.partition_of(slot.k, slot.b)
        while True:
            cands = [s for s in self.batcher.slots
                     if s.request is not None
                     and self.batcher.partition_of(s.k, s.b) == p
                     and not self._pair_in_flight(s)]
            if not cands:
                return False
            victim = max(cands,
                         key=lambda s: (s.admitted_tick, s.request.rid))
            self._retract(victim)
            if slot.request is None:  # the requester (or its pair) lost
                return False
            if slot.table.ensure(slot.pos + extra):
                return True

    def _pair_in_flight(self, slot) -> bool:
        """Whether any block of ``slot``'s table — or its speculation
        peer's — is an in-flight transfer destination (such a pair cannot
        be retracted: the pending bytes' home would be reallocated)."""
        for s in (slot, slot.peer):
            if s is None or s.table is None:
                continue
            p = self.batcher.partition_of(s.k, s.b)
            if any(self.transfer.in_flight(p, b) for b in s.table.blocks):
                return True
        return False

    def _retract(self, victim) -> None:
        """Preempt a running request: swap its blocks to host when the tier
        has room (decode-phase rows only — their whole KV is generated
        state), else remember its tokens for a teacher-forced recompute
        replay; release the cell and requeue the request at its queue head
        with its original admission tick (so restore order is stable and a
        freshly restored row is not the next victim).

        A speculation pair is preempted atomically: a drafter victim is
        redirected to its target peer (the request lives there), only the
        target's KV is swapped/replayed — drafter KV is disposable, rebuilt
        by catch-up from position 0 after re-admission — and both cells
        release."""
        if victim.is_draft and victim.peer is not None:
            victim = victim.peer
        req = victim.request
        peer = victim.peer
        p = self.batcher.partition_of(victim.k, victim.b)
        gen = (list(victim.generated) if victim.generated
               else (list(victim.resume_tokens)
                     if victim.resume_tokens else []))
        state = None
        if gen and not victim.chunks:
            state = self._swap_out_victim(victim, p, gen)
        if state is None and gen:
            state = ResumeState(generated=gen, pos=victim.pos,
                                admitted_tick=victim.admitted_tick,
                                first_token_tick=victim.first_token_tick)
        tr = self.trace
        if tr.enabled:
            swapped = state is not None and state.host_ids is not None
            if swapped:
                tr.req("swap_out", req.rid, blocks=len(state.host_ids))
            via = ("swap" if swapped
                   else "recompute" if state is not None else "requeue")
            tr.req("retract", req.rid, via=via, pos=victim.pos)
            self._retracted.add(req.rid)
        victim.release()
        if peer is not None:
            peer.release()
        self.batcher.requeue(req, state)
        self.stats.retractions += 1

    def _swap_out_victim(self, victim, p, gen):
        """Extract the victim's whole block table to pinned host blocks.
        Returns a swap ResumeState, or None when the host tier cannot take
        the full table (partial swaps are useless — fall back to replay)."""
        st = self.store
        ids = list(victim.table.blocks)
        if not (st.spill and st.host_capacity >= len(ids)):
            return None
        payloads = self.transfer.swap_out(p, ids)
        hids = []
        for payload in payloads:
            hid = st.host_put(p, payload, pinned=True)
            if hid is None:  # tier full of pinned/interior blocks: roll back
                for h in hids:
                    st.host_pop(p, h)
                self.transfer.swap_out_blocks -= len(payloads)
                return None
            hids.append(hid)
        return ResumeState(generated=gen, pos=victim.pos,
                           admitted_tick=victim.admitted_tick,
                           first_token_tick=victim.first_token_tick,
                           partition=p, host_ids=hids)

    def _cow_forks(self, slots, extra) -> list:
        """Enforce the writer-exclusivity invariant: any *shared* block
        (refcount > 1) overlapping a row's next write range [pos, pos+extra)
        is forked — a private block is allocated, a pool copy is enqueued on
        the transfer engine (flushed once per round), and the table entry
        swaps — before the write is issued. Only the partially-matched tail
        block of a prefix hit can ever be shared in a write range, so forks
        are rare."""
        if self.prefix_cache is None:
            return list(slots)
        ready = []
        for s in slots:
            pairs = s.table.fork_shared(s.pos, s.pos + extra)
            if pairs is None:  # pool can't back the fork: stall this row
                self.stats.pool_stalls += 1
                continue
            p = self.batcher.partition_of(s.k, s.b)
            for src, dst in pairs:
                s.cached_ids.discard(src)  # no longer pinned by this slot
                self.transfer.copy(p, src, dst)
            ready.append(s)
        return ready

    def _assert_clean(self, slots, extra) -> None:
        """Compute-call precondition: no participating block is mid-transfer,
        and every block in a row's write range is exclusively owned."""
        bs = self.eng.block_size
        for s in slots:
            p = self.batcher.partition_of(s.k, s.b)
            assert not any(self.transfer.in_flight(p, b)
                           for b in s.table.blocks), \
                "pipeline call would read an in-flight block"
            for j in range(s.pos // bs, blocks_for(s.pos + extra, bs)):
                assert self.allocator.ref_count(s.table.blocks[j], p) == 1, \
                    "write range overlaps a shared (refcount > 1) block"

    def _prefill_call(self, qlen: int, slots) -> None:
        slots = self._prepare(slots, qlen)
        if self.transfer is not None:
            # batched flush: this call's CoW forks plus any admission-time
            # swap-ins land in ONE transfer round before the compute reads
            self.transfer.flush()
        if not slots:
            return
        if self.paged:
            self._assert_clean(slots, qlen)
        tokens, positions, active = self._grid(qlen)
        for s in slots:
            tokens[s.k, s.m, s.b] = s.chunks[0]
            positions[s.k, s.m, s.b] = s.pos
            active[s.k, s.m, s.b] = True
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(positions),
                 "active": jnp.asarray(active)}
        if self.paged:
            batch["block_tables"] = jnp.asarray(self._block_tables(slots))
        self.cache, tok, _ = self.append_step(self.params, self.cache, batch)
        tok = np.asarray(tok)
        self.stats.calls += 1
        self.stats.prefill_calls += 1
        self.stats.prefill_slot_ticks += len(slots)
        tr = self.trace
        if tr.enabled:
            self._round_modes.append(f"append:{qlen}")
        for s in slots:
            if tr.enabled:
                tr.req("prefill_chunk", s.request.rid, k=s.k, m=s.m, b=s.b,
                       qlen=qlen, pos=s.pos)
            s.chunks.pop(0)
            s.pos += qlen
            if not s.chunks:
                t = int(tok[s.k, s.m, s.b])
                if s.resume_tokens is not None:
                    # recompute-restore replay: the final chunk re-derives
                    # the victim's LAST pre-retraction token — it must match
                    # bit-for-bit and is not re-counted (already generated)
                    assert t == s.resume_tokens[-1], \
                        "recompute replay diverged from retracted tokens"
                    s.generated = list(s.resume_tokens)
                    s.resume_tokens = None
                else:  # final chunk → first generated token
                    s.generated.append(t)
                    s.first_token_tick = self.tick
                    self.stats.tokens_generated += 1
                    if tr.enabled:
                        tr.req("first_token", s.request.rid)
                self._maybe_finish(s)

    def _decode_call(self, slots, sample: bool = True) -> int:
        """One decode-mode pipeline call for ``slots``; returns the number of
        rows that actually ran (pool stalls drop rows). ``sample=False``
        suppresses the per-round occupancy sample (the fused path records one
        combined sample covering the mixed call plus this tail call)."""
        slots = self._prepare(slots, 1)
        if self.transfer is not None:
            self.transfer.flush()
        if not slots:
            # a fully pool-stalled decode round is zero decode work, not a
            # skipped sample — keep the occupancy metric honest
            if sample:
                self.stats.decode_busy_samples.append(0.0)
            return 0
        if self.paged:
            self._assert_clean(slots, 1)
        tokens, positions, active = self._grid(1)
        for s in slots:
            tokens[s.k, s.m, s.b, 0] = s.generated[-1]
            positions[s.k, s.m, s.b] = s.pos
            active[s.k, s.m, s.b] = True
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(positions),
                 "active": jnp.asarray(active)}
        if self.paged:
            batch["block_tables"] = jnp.asarray(self._block_tables(slots))
        self.cache, tok, _ = self.decode_step(self.params, self.cache, batch)
        tok = np.asarray(tok)
        self.stats.calls += 1
        if self.trace.enabled:
            self._round_modes.append("decode")
        if sample:
            self.stats.decode_busy_samples.append(
                len(slots) / self.batcher.n_cells)
        for s in slots:
            s.pos += 1
            s.generated.append(int(tok[s.k, s.m, s.b]))
            self.stats.tokens_generated += 1
            self._maybe_finish(s)
        return len(slots)

    # -- gang speculation ----------------------------------------------------

    def _spec_round(self, slots) -> None:
        """One propose–verify–commit round for the paired decoding targets.

        Each target's drafter first *catches up* to the committed stream
        (one append covering every position the drafter has not yet
        written — after a full accept that is 2 tokens, after a partial
        accept 1, after admission the whole prompt), emitting its first
        proposal; ``spec_gamma - 1`` width-1 drafter decodes extend the
        draft. The target then scores all drafts in ONE ragged verify call
        (per-row qlens + per-position argmax — PR 8's mixed-tick machinery),
        commits the longest matching prefix plus its own argmax at the first
        mismatch, and rolls rejected positions back. Greedy tokens are
        bit-identical to the target-only engine by construction: every
        committed token is the target's own argmax at its own position —
        drafter quality moves only the acceptance rate.
        """
        plan, drafts = {}, {}
        for s in slots:
            remaining = s.request.max_new_tokens - len(s.generated)
            # never draft the request's final token: it is emitted by the
            # verify head and has no successor to verify against
            plan[id(s)] = min(self.spec_gamma, max(remaining - 1, 0))
            drafts[id(s)] = []
        widths: dict = {}
        for s in slots:
            if plan[id(s)] > 0:
                widths.setdefault(s.pos + 1 - s.peer.pos, []).append(s)
        for w in sorted(widths):
            self._draft_call(w, widths[w], drafts)
        for i in range(1, self.spec_gamma):
            group = [s for s in slots
                     if s.request is not None and plan[id(s)] > i
                     and len(drafts[id(s)]) == i]
            if group:
                self._draft_call(1, group, drafts)
        live = [s for s in slots if s.request is not None]
        if live:
            self._verify_call(live, drafts)

    def _draft_call(self, w: int, group, drafts) -> None:
        """One width-``w`` pipeline call on the drafter rows of ``group``:
        each drafter consumes ``w`` tokens of its extended stream
        (prompt ++ committed ++ drafts-so-far) from its own depth and its
        head output is appended to the pair's draft list."""
        dslots = self._prepare([s.peer for s in group], w)
        if self.transfer is not None:
            self.transfer.flush()
        group = [s for s in group if s.request is not None
                 and s.peer is not None and s.peer in dslots]
        if not group:
            return
        if self.paged:
            self._assert_clean([s.peer for s in group], w)
        tokens, positions, active = self._grid(w)
        for s in group:
            d = s.peer
            ext = s.request.prompt.tolist() + s.generated + drafts[id(s)]
            tokens[d.k, d.m, d.b, :] = ext[d.pos:d.pos + w]
            positions[d.k, d.m, d.b] = d.pos
            active[d.k, d.m, d.b] = True
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(positions),
                 "active": jnp.asarray(active)}
        if self.paged:
            batch["block_tables"] = jnp.asarray(
                self._block_tables([s.peer for s in group]))
        step = self.decode_step if w == 1 else self.append_step
        self.cache, tok, _ = step(self.params, self.cache, batch)
        tok = np.asarray(tok)
        self.stats.calls += 1
        self.spec_stats.draft_calls += 1
        if self.trace.enabled:
            self._round_modes.append(f"draft:{w}")
        for s in group:
            d = s.peer
            d.pos += w
            drafts[id(s)].append(int(tok[d.k, d.m, d.b]))

    def _verify_call(self, slots, drafts) -> None:
        """ONE ragged verify call scoring every pair's drafts on the target
        rows, then per-pair accept/commit/rollback."""
        ready = []
        for s in slots:
            if s.request is None:
                continue
            extra = len(drafts[id(s)]) + 1
            if self.paged and not self._ensure(s, extra):
                if s.request is not None:
                    self.stats.pool_stalls += 1
                continue
            ready.append(s)
        ready = [s for s in ready if s.request is not None]
        if self.prefix_cache is not None:
            ready = [s for s in ready
                     if self._cow_forks([s], len(drafts[id(s)]) + 1)]
        if self.transfer is not None:
            self.transfer.flush()
        if not ready:
            self.stats.decode_busy_samples.append(0.0)
            return
        if self.paged:
            for s in ready:
                self._assert_clean([s], len(drafts[id(s)]) + 1)
        qmax = max(len(drafts[id(s)]) for s in ready) + 1
        tokens, positions, active = self._grid(qmax)
        qlens = np.zeros((self.n_arches, self.eng.n_microbatches,
                          self.mb_global), np.int32)
        for s in ready:
            ds = drafts[id(s)]
            q = len(ds) + 1
            # re-feed the last committed token (its KV row is unwritten —
            # decode-style), then the drafts; the verify head returns the
            # target's argmax at every one of the q positions
            tokens[s.k, s.m, s.b, :q] = [s.generated[-1]] + ds
            positions[s.k, s.m, s.b] = s.pos
            qlens[s.k, s.m, s.b] = q
            active[s.k, s.m, s.b] = True
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(positions),
                 "qlens": jnp.asarray(qlens),
                 "active": jnp.asarray(active)}
        if self.paged:
            batch["block_tables"] = jnp.asarray(self._block_tables(ready))
        self.cache, tok, _ = self.verify_step(self.params, self.cache, batch)
        tok = np.asarray(tok)  # (K, M, mb_global, qmax)
        self.stats.calls += 1
        sp = self.spec_stats
        sp.verify_calls += 1
        tr = self.trace
        if tr.enabled:
            self._round_modes.append("verify")
        self.stats.decode_busy_samples.append(
            len(ready) / self.batcher.n_cells)
        for s in ready:
            ds = drafts[id(s)]
            out = [int(t) for t in tok[s.k, s.m, s.b, :len(ds) + 1]]
            n_acc = 0
            while n_acc < len(ds) and ds[n_acc] == out[n_acc]:
                n_acc += 1
            # accepted prefix + the target's own token at the first mismatch
            # (or the bonus token after a full accept) — always >= 1 token,
            # so a round never regresses below plain decode
            commit = ds[:n_acc] + [out[n_acc]]
            sp.proposed += len(ds)
            sp.accepted += n_acc
            sp.bonus += 1
            if tr.enabled:
                tr.req("spec_propose", s.request.rid, n=len(ds))
                tr.req("spec_verify", s.request.rid, accepted=n_acc,
                       committed=len(commit))
            new_pos = s.pos + n_acc + 1
            d = s.peer
            rolled = 0
            if self.paged and n_acc < len(ds):
                # rejected positions' blocks go back to the free-list head:
                # pool state is bit-identical to never having written them
                rolled += len(s.table.truncate(new_pos))
            if d is not None and d.pos > new_pos:
                if self.paged:
                    rolled += len(d.table.truncate(new_pos))
                d.pos = new_pos  # rewind over the rejected draft positions
            sp.rollback_blocks += rolled
            if tr.enabled and n_acc < len(ds):
                tr.req("rollback", s.request.rid, blocks=rolled,
                       rejected=len(ds) - n_acc)
            s.pos = new_pos
            s.generated.extend(commit)
            self.stats.tokens_generated += len(commit)
            self._maybe_finish(s)

    def _mixed_call(self) -> None:
        """One fused mixed-tick pipeline call for the whole round: every
        prefilling cell rides at its chunk width, every decoding cell at
        qlen 1, idle cells at qlen 0 — one shared active mask, per-row
        positions/kv offsets, rows padded to the wave max. Only rows whose
        chunk completes the prompt (and the decode rows) sample a token.

        Schedule parity with the split path is exact: slots are *prepared*
        (block growth, retraction, CoW) in the split order — per sorted qlen
        group then decode, each followed by a transfer flush — and a slot
        that finishes its final chunk here also decodes once more this same
        round via a tail decode call, mirroring the split schedule where
        ``decode_slots()`` is taken after the prefill waves. Greedy tokens
        and (preemption-free) per-request tick latencies are therefore
        bit-identical; under retraction the atomic round preempts a wave
        row before its chunk runs (split preempts after), so preemption
        timing may differ — tokens still never change."""
        pre = []
        for qlen, slots in sorted(self.batcher.prefill_groups().items()):
            ready = self._prepare(slots, qlen)
            if self.transfer is not None:
                self.transfer.flush()
            pre.extend((s, qlen) for s in ready)
        dec_all = self.batcher.decode_slots()
        dec = self._prepare(dec_all, 1)
        if self.transfer is not None:
            self.transfer.flush()
        # a later group's retraction may have victimized an earlier-prepared
        # row — drop released slots before building the wave
        pre = [(s, q) for s, q in pre if s.request is not None]
        dec = [s for s in dec if s.request is not None]
        if not pre and not dec:
            if dec_all:
                self.stats.decode_busy_samples.append(0.0)
            return
        if self.paged:
            for s, q in pre:
                self._assert_clean([s], q)
            self._assert_clean(dec, 1)
        qmax = max(q for _, q in pre) if pre else 1
        tokens, positions, active = self._grid(qmax)
        qlens = np.zeros((self.n_arches, self.eng.n_microbatches,
                          self.mb_global), np.int32)
        for s, q in pre:
            tokens[s.k, s.m, s.b, :q] = s.chunks[0]
            positions[s.k, s.m, s.b] = s.pos
            qlens[s.k, s.m, s.b] = q
            active[s.k, s.m, s.b] = True
        for s in dec:
            tokens[s.k, s.m, s.b, 0] = s.generated[-1]
            positions[s.k, s.m, s.b] = s.pos
            qlens[s.k, s.m, s.b] = 1
            active[s.k, s.m, s.b] = True
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(positions),
                 "qlens": jnp.asarray(qlens),
                 "active": jnp.asarray(active)}
        if self.paged:
            batch["block_tables"] = jnp.asarray(
                self._block_tables([s for s, _ in pre] + dec))
        self.cache, tok, _ = self.mixed_step(self.params, self.cache, batch)
        tok = np.asarray(tok)
        self.stats.calls += 1
        self.stats.mixed_calls += 1
        self.stats.prefill_slot_ticks += len(pre)
        fill = float(qlens.sum()) / (self.batcher.n_cells * qmax)
        self.stats.mixed_fill_samples.append(fill)
        tr = self.trace
        if tr.enabled:
            self._round_modes.append(f"mixed:{round(fill, 4)}")
        tail = []  # final-chunk completions decode again this round
        for s, q in pre:
            if tr.enabled:
                tr.req("prefill_chunk", s.request.rid, k=s.k, m=s.m, b=s.b,
                       qlen=q, pos=s.pos)
            s.chunks.pop(0)
            s.pos += q
            if not s.chunks:
                t = int(tok[s.k, s.m, s.b])
                if s.resume_tokens is not None:
                    assert t == s.resume_tokens[-1], \
                        "recompute replay diverged from retracted tokens"
                    s.generated = list(s.resume_tokens)
                    s.resume_tokens = None
                else:  # final chunk → first generated token
                    s.generated.append(t)
                    s.first_token_tick = self.tick
                    self.stats.tokens_generated += 1
                    if tr.enabled:
                        tr.req("first_token", s.request.rid)
                self._maybe_finish(s)
                if s.request is not None:
                    tail.append(s)
        for s in dec:
            s.pos += 1
            s.generated.append(int(tok[s.k, s.m, s.b]))
            self.stats.tokens_generated += 1
            self._maybe_finish(s)
        ran = self._decode_call(tail, sample=False) if tail else 0
        if dec_all or tail:
            self.stats.decode_busy_samples.append(
                (len(dec) + ran) / self.batcher.n_cells)

    def _maybe_finish(self, slot) -> None:
        if not slot.finished:
            return
        req = slot.request
        if self.prefix_cache is not None:
            # cache instead of free: adopt the request's full prompt blocks
            # into the radix tree (they keep one tree reference when the
            # table closes in release() below)
            self.prefix_cache.insert(
                self.batcher.partition_of(slot.k, slot.b),
                req.prompt, slot.table.blocks)
        comp = Completion(
            rid=req.rid, prompt_len=req.prompt_len,
            tokens=list(slot.generated[:req.max_new_tokens]),
            arrival=req.arrival, admitted_tick=slot.admitted_tick,
            finished_tick=self.tick, arch=req.arch,
            first_token_tick=slot.first_token_tick)
        self.completions.append(comp)
        self.stats.record_completion(comp)
        if self.trace.enabled:
            self.trace.req("complete", req.rid, tokens=len(comp.tokens),
                           ttft=comp.ttft_ticks)
        peer = slot.peer
        slot.release()  # the cell is reusable the same round it finishes
        if peer is not None:  # the drafter mirror cell frees with its target
            peer.release()


# ---------------------------------------------------------------------------
# Static-batching baseline (the seed's lockstep path, instrumented)
# ---------------------------------------------------------------------------


def static_serve(cfg: ArchConfig, eng: pl.EngineConfig, mesh, params,
                 requests, opts: Optional[ModelOptions] = None):
    """Lockstep static batching over the same slot grid, for comparison.

    Single-arch (trial row 0 only — the lockstep baseline has no routing).
    Admits requests in consecutive groups of ``n_cells``, prefills each group
    at once (prompts must share one length — the static path's restriction),
    then decodes until EVERY request in the group hits its budget; early
    finishers idle their slots. Arrival times are ignored (a clairvoyant
    static scheduler — flatters the baseline). Returns
    (completions, ServeStats).
    """
    opts = opts or ModelOptions()
    # the lockstep baseline keeps dense per-slot strips (it IS the worst-case
    # reservation the paged engine is measured against)
    eng = dataclasses.replace(eng, n_trials=1, prefill_chunks=1, paged=False,
                              n_blocks=0)
    mb_global = eng.microbatch * (1 if eng.batch_replicated
                                  else eng.data_size * eng.pod_size)
    n_cells = eng.n_microbatches * mb_global
    prefill = pl.make_serve_step(cfg, opts, eng, mesh, "prefill")
    decode = pl.make_serve_step(cfg, opts, eng, mesh, "decode")
    stats = ServeStats()
    completions = []
    reqs = list(requests)
    t0 = time.monotonic()
    for g0 in range(0, len(reqs), n_cells):
        group = reqs[g0:g0 + n_cells]
        plens = {r.prompt_len for r in group}
        if len(plens) != 1:
            raise ValueError("static batching requires uniform prompt "
                             f"lengths per group, got {sorted(plens)}")
        plen = plens.pop()
        tokens = np.zeros((1, eng.n_microbatches, mb_global, plen), np.int32)
        for i, r in enumerate(group):
            tokens[0, i // mb_global, i % mb_global] = r.prompt
        cache = pl.serve_cache_struct(cfg, eng, dry_run=False)
        stats.ticks += 1
        admitted_tick = stats.ticks  # the group's prefill tick
        stats.calls += 1
        stats.occupancy_samples.append(len(group) / n_cells)
        stats.prompt_tokens += plen * len(group)
        cache, tok, _ = prefill(params, cache, {"tokens": jnp.asarray(tokens)})
        gen = [np.asarray(tok)]
        stats.tokens_generated += len(group)
        max_gen = max(r.max_new_tokens for r in group)
        pos = plen
        for t in range(1, max_gen):
            live = sum(1 for r in group if r.max_new_tokens > t)
            stats.ticks += 1
            stats.calls += 1
            stats.occupancy_samples.append(live / n_cells)
            stats.decode_busy_samples.append(live / n_cells)
            cache, tok, _ = decode(params, cache, {
                "tokens": jnp.asarray(gen[-1][..., None]),
                "positions": jnp.full((1, eng.n_microbatches, mb_global),
                                      pos, jnp.int32)})
            gen.append(np.asarray(tok))
            stats.tokens_generated += live
            pos += 1
        toks = np.stack(gen, axis=-1)  # (1, M, mbg, max_gen)
        for i, r in enumerate(group):
            comp = Completion(
                rid=r.rid, prompt_len=plen,
                tokens=toks[0, i // mb_global, i % mb_global,
                            :r.max_new_tokens].tolist(),
                arrival=r.arrival, admitted_tick=admitted_tick,
                # the decode tick that produced the request's last token
                # (its slot still idles until the group drains)
                finished_tick=admitted_tick + r.max_new_tokens - 1,
                arch=r.arch, first_token_tick=admitted_tick)
            completions.append(comp)
            stats.record_completion(comp)
    stats.wall_s = time.monotonic() - t0
    return sorted(completions, key=lambda c: c.rid), stats
