"""Continuous-batching serve engine over the Hydra pipeline.

The static path in ``launch/serve.py --static`` admits one fixed batch, runs
prefill once, and decodes in lockstep — when a request finishes early its
pipeline slot idles until the whole batch drains, the exact "idle slots"
pathology the paper's shard parallelism exists to kill. This engine applies
the same slot-filling insight to a *dynamic* request stream — and, like the
paper's gangs, to a dynamic stream addressed to *several model variants at
once*: the slot grid is (trial k, microbatch m, batch-row b), trial row k
holds variant k's weights, and the batcher routes each request's arch id to
its own trial rows, so one gang-scheduled SPMD program co-serves K
architectures (the serving analogue of Hydra/Saturn gang planning).

Cell lifecycle (one cell = one (k, m, b) position of the pipelined serve
step, owning one KV/SSM-cache row of trial k; requests with ``arch == k``
are the only ones that ever occupy it):

  FREE ──admit──► PREFILL ──last chunk──► DECODE ──budget hit──► FREE
   ▲   (arch k's queue head moves into a       (one token per engine round │
   │    free (k, m, b) cell; cache row          via the masked decode      │
   │    zeroed — KV rows beyond kv_len are      step; per-row positions;   │
   │    never attended, but SSM states are      every trial row decodes in │
   │    recurrent and must restart from zero)   the same pipeline call)    │
   └──────────────────────────────────────────────────────────────────────┘

Paged mode (``eng.paged``) replaces the per-cell dense cache strips with one
block pool per (trial, layer) (``serve/paging.py``) — the pool leaf carries a
leading K axis, so each variant's blocks are physically its own slice and the
allocator is partitioned per (trial, data-shard). The cache column of the
lifecycle becomes block-table bookkeeping:

  FREE ──admit──► PREFILL ──last chunk──► DECODE ──budget hit──► FREE
   ▲   (admission defers — per-arch           (crossing a block boundary  │
   │    backpressure, other arches keep        allocs one block:          │
   │    flowing — until the request's exact    alloc-on-append)           │
   │    block commitment fits trial k's                                   │
   │    partition; each prefill chunk grows                               │
   │    the cell's block table; no cache                                  │
   │    zeroing — stale blocks are masked                                 │
   │    by kv_len)                                                        │
   └────────────── blocks returned to the allocator's free list ──────────┘

Short requests then stop reserving ``max_seq``-worst-case HBM, so
``plan_serve_capacity(paged=True)`` packs strictly more concurrent cells
into the same budget (admission by *expected* length against the pool; a
traffic ``mix`` sizes the grid for K arches' expected lengths and arrival
weights at once).

Prefix caching (``prefix_cache=True``, paged only) adds cross-request KV
sharing on top: completed requests insert their prompt blocks into a radix
tree (``serve/prefix_cache.py``) instead of dropping them, admission matches
each prompt against the tree and seeds the slot from the cached block table
at ``pos`` = hit length (chunked prefill starts at the hit boundary — whole
prefill waves are skipped, so TTFT drops with hit length), and a write into
a partially-matched shared tail block first forks it copy-on-write via a
device pool copy (``make_block_copy``) — greedy tokens stay bit-identical
with the cache on or off. Unreferenced cached blocks are reclaimed LRU when
the pool runs dry, so the cache never deadlocks admission.

* **Admission / chunked prefill.** A prompt is split into
  ``EngineConfig.prefill_chunks`` near-equal chunks; each engine round
  advances every prefilling cell by one chunk via the ``append`` serve step
  (per-row kv offsets — cells in the same call may sit at different depths,
  and cells of *different trial rows* ride in the same call: the step
  indexes params, caches, and block tables by each cell's k). Calls are
  grouped by chunk length so token shapes stay static; the final chunk's
  head output is the request's first generated token. Admission order
  within an arch follows the batcher ``policy`` (fcfs / sjf / deadline).
* **Recycling.** The round a request exhausts its budget, its cell is
  released and the cache row is zeroed (``make_slot_reset``); the next
  queued request of that arch is admitted the same round. Slots therefore
  never idle while their arch's queue is non-empty — steady-state occupancy
  stays ~1 where the static path decays as a batch drains.
* **Sliding window.** ``eng.window`` > 0 (attention-only archs) bounds every
  query to the trailing window: the cache keeps its absolute ``max_seq``
  layout and the append/decode steps mask positions ≤ pos − window, so
  greedy tokens match a windowed single-device oracle exactly.
* **Exactness.** Every active row always processes exactly its own real
  tokens at its own positions against its own trial's weights, so greedy
  tokens match serving that row's arch alone through a single-arch engine
  (and the single-device oracle) per request, bit-for-bit.

Per-request completion is exposed as :class:`repro.serve.request.Completion`
records (with TTFT/TPOT tick latencies) instead of lockstep tensors.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import pipeline as pl
from repro.models.layers import ModelOptions
from repro.serve.batcher import Batcher
from repro.serve.paging import BlockAllocator, blocks_for
from repro.serve.prefix_cache import PrefixCache
from repro.serve.request import Completion, Request


def _pctl(samples, q) -> float:
    return float(np.percentile(np.asarray(samples, np.float64), q))


@dataclasses.dataclass
class ServeStats:
    """Scheduling/throughput counters for one engine run."""

    ticks: int = 0
    calls: int = 0
    prefill_calls: int = 0  # append-mode pipeline calls (prefill waves)
    prefill_slot_ticks: int = 0  # (cell, round) pairs spent prefilling —
    # the per-request prefill-tick total (calls group concurrent cells, so
    # this is the measure a prefix-cache hit actually shrinks)
    tokens_generated: int = 0
    prompt_tokens: int = 0
    wall_s: float = 0.0
    peak_live: int = 0  # max concurrently admitted requests (capacity used)
    pool_stalls: int = 0  # paged: row-rounds deferred on an exhausted pool
    prefix_enabled: bool = False  # radix prefix cache active
    prefix_hits: int = 0  # admitted requests with a non-empty prefix hit
    prefix_hit_tokens: int = 0  # prompt tokens served from cached blocks
    prefix_inserts: int = 0  # blocks adopted into the radix tree
    prefix_evictions: int = 0  # cached blocks reclaimed under pool pressure
    cow_forks: int = 0  # shared tail blocks forked copy-on-write
    occupancy_samples: list = dataclasses.field(default_factory=list)
    decode_busy_samples: list = dataclasses.field(default_factory=list)
    block_usage_samples: list = dataclasses.field(default_factory=list)
    ttft_samples: list = dataclasses.field(default_factory=list)  # ticks
    tpot_samples: list = dataclasses.field(default_factory=list)  # ticks
    tokens_per_arch: dict = dataclasses.field(default_factory=dict)

    @property
    def slot_occupancy(self) -> float:
        """Mean fraction of slot cells holding a live request, sampled once
        per engine round — the paper's utilization story applied to serving."""
        if not self.occupancy_samples:
            return 0.0
        return float(np.mean(self.occupancy_samples))

    @property
    def decode_occupancy(self) -> float:
        """Mean busy fraction of the decode step's rows."""
        if not self.decode_busy_samples:
            return 0.0
        return float(np.mean(self.decode_busy_samples))

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_generated / self.wall_s if self.wall_s > 0 else 0.0

    def record_completion(self, comp: Completion) -> None:
        self.ttft_samples.append(comp.ttft_ticks)
        if len(comp.tokens) > 1:
            self.tpot_samples.append(comp.tpot_ticks)
        self.tokens_per_arch[comp.arch] = (
            self.tokens_per_arch.get(comp.arch, 0) + len(comp.tokens))

    def summary(self) -> dict:
        out = {"ticks": self.ticks, "calls": self.calls,
               "prefill_calls": self.prefill_calls,
               "prefill_slot_ticks": self.prefill_slot_ticks,
               "tokens_generated": self.tokens_generated,
               "prompt_tokens": self.prompt_tokens,
               "peak_live": self.peak_live,
               "slot_occupancy": round(self.slot_occupancy, 4),
               "decode_occupancy": round(self.decode_occupancy, 4),
               "tokens_per_s": round(self.tokens_per_s, 2)}
        if self.ttft_samples:
            out["ttft_p50"] = round(_pctl(self.ttft_samples, 50), 2)
            out["ttft_p95"] = round(_pctl(self.ttft_samples, 95), 2)
        if self.tpot_samples:
            out["tpot_p50"] = round(_pctl(self.tpot_samples, 50), 2)
            out["tpot_p95"] = round(_pctl(self.tpot_samples, 95), 2)
        if len(self.tokens_per_arch) > 1:
            out["tokens_per_arch"] = {
                k: self.tokens_per_arch[k]
                for k in sorted(self.tokens_per_arch)}
        if self.block_usage_samples:
            out["peak_blocks_in_use"] = int(max(self.block_usage_samples))
            out["pool_stalls"] = self.pool_stalls
        if self.prefix_enabled:
            out["prefix_hits"] = self.prefix_hits
            out["prefix_hit_tokens"] = self.prefix_hit_tokens
            out["prefix_inserts"] = self.prefix_inserts
            out["prefix_evictions"] = self.prefix_evictions
            out["cow_forks"] = self.cow_forks
        return out


class ServeEngine:
    """Continuous-batching engine: per-arch request queues → (k, m, b) cells.

    Parameters mirror the static path: ``eng.n_trials`` trial rows (one per
    co-served model variant — ``params`` carries each variant's weights on
    its leading K axis) × ``eng.n_microbatches`` × global microbatch rows
    define the slot grid, ``eng.max_seq`` bounds each cache row,
    ``eng.prefill_chunks`` sets the admission chunk count. ``eng`` is
    normalized to spatial-chunking off (the engine chunks *temporally*,
    across calls, so every microbatch slot owns one cache group).
    ``policy`` picks the per-arch admission order (fcfs / sjf / deadline).
    """

    def __init__(self, cfg: ArchConfig, eng: pl.EngineConfig, mesh, params,
                 opts: Optional[ModelOptions] = None,
                 overcommit: float = 1.0, policy: str = "fcfs",
                 prefix_cache: bool = False):
        if cfg.rope == "mrope" or cfg.frontend is not None:
            raise ValueError("continuous batching supports text-only archs; "
                             "use the static path for mrope/frontend models")
        if eng.window and (cfg.family in ("ssm", "hybrid")
                           or cfg.hybrid is not None):
            raise ValueError(
                "sliding-window continuous serving supports attention-only "
                "archs (SSM state is not positional; the hybrid shared cache "
                "is a window-sized ring the append step cannot address)")
        self.cfg = cfg
        self.opts = opts or ModelOptions()
        self.eng = dataclasses.replace(eng, prefill_chunks=1)
        self.n_arches = self.eng.n_trials
        self.n_chunks = max(1, eng.prefill_chunks)
        self.mesh = mesh
        self.params = params
        self.mb_global = self.eng.microbatch * (
            1 if self.eng.batch_replicated
            else self.eng.data_size * self.eng.pod_size)
        self.decode_step = pl.make_serve_step(
            cfg, self.opts, self.eng, mesh, "decode", with_active=True)
        self.append_step = pl.make_serve_step(
            cfg, self.opts, self.eng, mesh, "append", with_active=True)
        self.paged = bool(self.eng.paged)
        self.allocator = None
        if prefix_cache and not self.paged:
            raise ValueError("the radix prefix cache shares paged KV blocks; "
                             "enable eng.paged to use prefix_cache")
        if self.paged:
            # one pool partition per (trial, data/pod shard): each variant's
            # pool leaf slice is its own, and rows allocate only from the
            # partition their (k, shard) owns (tables carry local ids)
            n_parts = (1 if self.eng.batch_replicated
                       else self.eng.data_size * self.eng.pod_size)
            self.allocator = BlockAllocator(
                self.eng.n_blocks * self.n_arches, self.eng.block_size,
                n_partitions=self.n_arches * n_parts)
            self.max_blocks = blocks_for(self.eng.max_seq,
                                         self.eng.block_size)
            # no slot reset: paged serving is attention-only (no recurrent
            # state) and stale pool blocks are masked via kv_len
            self.reset_fn = None
        else:
            self.reset_fn = pl.make_slot_reset(cfg, self.eng, mesh)
        self.prefix_cache = None
        self.copy_fn = None
        if prefix_cache:
            self.prefix_cache = PrefixCache(self.allocator)
            self.copy_fn = pl.make_block_copy(cfg, self.eng, mesh)
        self.cache = pl.serve_cache_struct(cfg, self.eng, dry_run=False)
        self.batcher = Batcher(self.eng.n_microbatches, self.mb_global,
                               self.n_chunks, self.eng.max_seq,
                               n_trials=self.n_arches,
                               allocator=self.allocator,
                               rows_per_partition=self.eng.microbatch,
                               overcommit=overcommit, policy=policy,
                               prefix_cache=self.prefix_cache)
        self.tick = 0
        self._stalled_ticks = 0
        self.stats = ServeStats(prefix_enabled=prefix_cache)
        self.completions: list = []

    # -- public API ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.batcher.enqueue(req)

    def done(self) -> bool:
        return self.batcher.idle()

    def run(self, requests=None, max_ticks: int = 100_000) -> list:
        """Drive the engine until every submitted request completes."""
        for r in requests or []:
            self.submit(r)
        t0 = time.monotonic()
        while not self.done():
            if self.tick >= max_ticks:
                raise RuntimeError(f"engine did not drain in {max_ticks} "
                                   f"ticks ({self.batcher.occupied()} live)")
            self.step()
        self.stats.wall_s += time.monotonic() - t0
        return sorted(self.completions, key=lambda c: c.rid)

    # -- one scheduling round ------------------------------------------------

    def step(self) -> bool:
        """Admit → prefill wave → decode. Returns False when fully drained."""
        if self.done():
            return False
        self.tick += 1
        self.stats.ticks += 1
        calls_before = self.stats.calls
        admitted = self.batcher.admit(self.tick)
        if admitted:
            if not self.paged:
                self._reset_rows(admitted)
            self.stats.prompt_tokens += sum(
                s.request.prompt_len for s in admitted)
        occupied = self.batcher.occupied()
        self.stats.peak_live = max(self.stats.peak_live, occupied)
        self.stats.occupancy_samples.append(occupied / self.batcher.n_cells)
        if self.allocator is not None:
            self.stats.block_usage_samples.append(
                self.allocator.used_blocks())
        for qlen, slots in sorted(self.batcher.prefill_groups().items()):
            self._prefill_call(qlen, slots)
        dec = self.batcher.decode_slots()
        if dec:
            self._decode_call(dec)
        # overcommitted pools can stall every live row at a block boundary at
        # once; there is no preemption, so flag the deadlock instead of
        # spinning to max_ticks
        if occupied and self.stats.calls == calls_before and not admitted:
            self._stalled_ticks += 1
            if self._stalled_ticks > 100:
                raise RuntimeError(
                    "engine stalled: block pool exhausted with every live "
                    "row waiting for a block (overcommit too aggressive — "
                    "lower it toward 1.0 or grow n_blocks)")
        else:
            self._stalled_ticks = 0
        if self.prefix_cache is not None:
            # synced at end of round so this tick's completions (inserts)
            # and allocation-pressure evictions are already counted
            self.stats.prefix_hits = self.prefix_cache.hits
            self.stats.prefix_hit_tokens = self.prefix_cache.hit_tokens
            self.stats.prefix_inserts = self.prefix_cache.inserts
            self.stats.prefix_evictions = self.prefix_cache.evictions
        return True

    # -- internals -----------------------------------------------------------

    def _grid(self, qlen: int):
        k, m, b = self.n_arches, self.eng.n_microbatches, self.mb_global
        return (np.zeros((k, m, b, qlen), np.int32),
                np.zeros((k, m, b), np.int32),
                np.zeros((k, m, b), bool))

    def _reset_rows(self, slots) -> None:
        mask = np.zeros((self.n_arches, self.eng.n_microbatches,
                         self.mb_global), bool)
        for s in slots:
            mask[s.k, s.m, s.b] = True
        self.cache = self.reset_fn(self.cache, jnp.asarray(mask))

    def _block_tables(self, slots):
        """(K, M, mb_global, max_blocks) int32 local ids; rows not in the
        call stay -1 (their writes are dropped device-side anyway)."""
        bt = np.full((self.n_arches, self.eng.n_microbatches, self.mb_global,
                      self.max_blocks), -1, np.int32)
        for s in slots:
            bt[s.k, s.m, s.b] = s.table.as_row(self.max_blocks)
        return bt

    def _ensure_blocks(self, slots, extra) -> list:
        """Alloc-on-append: grow each slot's table to cover its next write.
        Rows the pool cannot back right now are stalled (kept out of this
        call, retried next round after completions free blocks)."""
        if not self.paged:
            return list(slots)
        ready = [s for s in slots if s.table.ensure(s.pos + extra)]
        self.stats.pool_stalls += len(slots) - len(ready)
        return self._cow_forks(ready, extra)

    def _cow_forks(self, slots, extra) -> list:
        """Enforce the writer-exclusivity invariant: any *shared* block
        (refcount > 1) overlapping a row's next write range [pos, pos+extra)
        is forked — a private block is allocated, the shared block's K/V is
        device-copied into it, and the table entry swaps — before the write
        is issued. Only the partially-matched tail block of a prefix hit can
        ever be shared in a write range, so forks are rare and batched into
        one pool-copy call per engine round."""
        if self.prefix_cache is None:
            return list(slots)
        ready, copies = [], []
        for s in slots:
            pairs = s.table.fork_shared(s.pos, s.pos + extra)
            if pairs is None:  # pool can't back the fork: stall this row
                self.stats.pool_stalls += 1
                continue
            for src, dst in pairs:
                s.cached_ids.discard(src)  # no longer pinned by this slot
                copies.append((s.k, s.b, src, dst))
            ready.append(s)
        if copies:
            self._flush_copies(copies)
            self.stats.cow_forks += len(copies)
        return ready

    def _flush_copies(self, copies) -> None:
        """Issue the batched device pool copies for this round's CoW forks.
        src/dst are (K, dp, C) local ids per (trial, shard) partition, -1
        padded; C is bucketed to powers of two to bound compile shapes."""
        n_sh = self.batcher.n_shards
        per: dict = {}
        for k, b, src, dst in copies:
            shard = self.batcher.partition_of(k, b) - k * n_sh
            per.setdefault((k, shard), []).append((src, dst))
        c = 1
        while c < max(len(v) for v in per.values()):
            c *= 2
        src = np.full((self.n_arches, n_sh, c), -1, np.int32)
        dst = np.full((self.n_arches, n_sh, c), -1, np.int32)
        for (k, sh), pairs in per.items():
            for j, (s_, d_) in enumerate(pairs):
                src[k, sh, j], dst[k, sh, j] = s_, d_
        self.cache = self.copy_fn(self.cache, jnp.asarray(src),
                                  jnp.asarray(dst))

    def _prefill_call(self, qlen: int, slots) -> None:
        slots = self._ensure_blocks(slots, qlen)
        if not slots:
            return
        tokens, positions, active = self._grid(qlen)
        for s in slots:
            tokens[s.k, s.m, s.b] = s.chunks[0]
            positions[s.k, s.m, s.b] = s.pos
            active[s.k, s.m, s.b] = True
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(positions),
                 "active": jnp.asarray(active)}
        if self.paged:
            batch["block_tables"] = jnp.asarray(self._block_tables(slots))
        self.cache, tok, _ = self.append_step(self.params, self.cache, batch)
        tok = np.asarray(tok)
        self.stats.calls += 1
        self.stats.prefill_calls += 1
        self.stats.prefill_slot_ticks += len(slots)
        for s in slots:
            s.chunks.pop(0)
            s.pos += qlen
            if not s.chunks:  # final chunk → first generated token
                s.generated.append(int(tok[s.k, s.m, s.b]))
                s.first_token_tick = self.tick
                self.stats.tokens_generated += 1
                self._maybe_finish(s)

    def _decode_call(self, slots) -> None:
        slots = self._ensure_blocks(slots, 1)
        if not slots:
            # a fully pool-stalled decode round is zero decode work, not a
            # skipped sample — keep the occupancy metric honest
            self.stats.decode_busy_samples.append(0.0)
            return
        tokens, positions, active = self._grid(1)
        for s in slots:
            tokens[s.k, s.m, s.b, 0] = s.generated[-1]
            positions[s.k, s.m, s.b] = s.pos
            active[s.k, s.m, s.b] = True
        batch = {"tokens": jnp.asarray(tokens),
                 "positions": jnp.asarray(positions),
                 "active": jnp.asarray(active)}
        if self.paged:
            batch["block_tables"] = jnp.asarray(self._block_tables(slots))
        self.cache, tok, _ = self.decode_step(self.params, self.cache, batch)
        tok = np.asarray(tok)
        self.stats.calls += 1
        self.stats.decode_busy_samples.append(
            len(slots) / self.batcher.n_cells)
        for s in slots:
            s.pos += 1
            s.generated.append(int(tok[s.k, s.m, s.b]))
            self.stats.tokens_generated += 1
            self._maybe_finish(s)

    def _maybe_finish(self, slot) -> None:
        if not slot.finished:
            return
        req = slot.request
        if self.prefix_cache is not None:
            # cache instead of free: adopt the request's full prompt blocks
            # into the radix tree (they keep one tree reference when the
            # table closes in release() below)
            self.prefix_cache.insert(
                self.batcher.partition_of(slot.k, slot.b),
                req.prompt, slot.table.blocks)
        comp = Completion(
            rid=req.rid, prompt_len=req.prompt_len,
            tokens=list(slot.generated[:req.max_new_tokens]),
            arrival=req.arrival, admitted_tick=slot.admitted_tick,
            finished_tick=self.tick, arch=req.arch,
            first_token_tick=slot.first_token_tick)
        self.completions.append(comp)
        self.stats.record_completion(comp)
        slot.release()  # the cell is reusable the same round it finishes


# ---------------------------------------------------------------------------
# Static-batching baseline (the seed's lockstep path, instrumented)
# ---------------------------------------------------------------------------


def static_serve(cfg: ArchConfig, eng: pl.EngineConfig, mesh, params,
                 requests, opts: Optional[ModelOptions] = None):
    """Lockstep static batching over the same slot grid, for comparison.

    Single-arch (trial row 0 only — the lockstep baseline has no routing).
    Admits requests in consecutive groups of ``n_cells``, prefills each group
    at once (prompts must share one length — the static path's restriction),
    then decodes until EVERY request in the group hits its budget; early
    finishers idle their slots. Arrival times are ignored (a clairvoyant
    static scheduler — flatters the baseline). Returns
    (completions, ServeStats).
    """
    opts = opts or ModelOptions()
    # the lockstep baseline keeps dense per-slot strips (it IS the worst-case
    # reservation the paged engine is measured against)
    eng = dataclasses.replace(eng, n_trials=1, prefill_chunks=1, paged=False,
                              n_blocks=0)
    mb_global = eng.microbatch * (1 if eng.batch_replicated
                                  else eng.data_size * eng.pod_size)
    n_cells = eng.n_microbatches * mb_global
    prefill = pl.make_serve_step(cfg, opts, eng, mesh, "prefill")
    decode = pl.make_serve_step(cfg, opts, eng, mesh, "decode")
    stats = ServeStats()
    completions = []
    reqs = list(requests)
    t0 = time.monotonic()
    for g0 in range(0, len(reqs), n_cells):
        group = reqs[g0:g0 + n_cells]
        plens = {r.prompt_len for r in group}
        if len(plens) != 1:
            raise ValueError("static batching requires uniform prompt "
                             f"lengths per group, got {sorted(plens)}")
        plen = plens.pop()
        tokens = np.zeros((1, eng.n_microbatches, mb_global, plen), np.int32)
        for i, r in enumerate(group):
            tokens[0, i // mb_global, i % mb_global] = r.prompt
        cache = pl.serve_cache_struct(cfg, eng, dry_run=False)
        stats.ticks += 1
        admitted_tick = stats.ticks  # the group's prefill tick
        stats.calls += 1
        stats.occupancy_samples.append(len(group) / n_cells)
        stats.prompt_tokens += plen * len(group)
        cache, tok, _ = prefill(params, cache, {"tokens": jnp.asarray(tokens)})
        gen = [np.asarray(tok)]
        stats.tokens_generated += len(group)
        max_gen = max(r.max_new_tokens for r in group)
        pos = plen
        for t in range(1, max_gen):
            live = sum(1 for r in group if r.max_new_tokens > t)
            stats.ticks += 1
            stats.calls += 1
            stats.occupancy_samples.append(live / n_cells)
            stats.decode_busy_samples.append(live / n_cells)
            cache, tok, _ = decode(params, cache, {
                "tokens": jnp.asarray(gen[-1][..., None]),
                "positions": jnp.full((1, eng.n_microbatches, mb_global),
                                      pos, jnp.int32)})
            gen.append(np.asarray(tok))
            stats.tokens_generated += live
            pos += 1
        toks = np.stack(gen, axis=-1)  # (1, M, mbg, max_gen)
        for i, r in enumerate(group):
            comp = Completion(
                rid=r.rid, prompt_len=plen,
                tokens=toks[0, i // mb_global, i % mb_global,
                            :r.max_new_tokens].tolist(),
                arrival=r.arrival, admitted_tick=admitted_tick,
                # the decode tick that produced the request's last token
                # (its slot still idles until the group drains)
                finished_tick=admitted_tick + r.max_new_tokens - 1,
                arch=r.arch, first_token_tick=admitted_tick)
            completions.append(comp)
            stats.record_completion(comp)
    stats.wall_s = time.monotonic() - t0
    return sorted(completions, key=lambda c: c.rid), stats
