"""Fault-tolerant training loop: checkpoint/restart, preemption handling,
failure injection (for tests) and straggler notes.

Straggler mitigation at Hydra's granularity: the compiled SPMD program has no
software stragglers (every device runs the same schedule); *hardware*
stragglers/failures surface as a lost mesh slice. Policy: checkpoint-restart
with the data axis shrunk around the cordoned slice
(``scheduler.replan_after_failure`` / ``runtime.elastic``) — gradients are
unchanged because the global batch is re-sharded, not re-sized, and the data
pipeline is deterministic per (trial, step).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Optional

from repro.checkpoint import ckpt as ckpt_lib


@dataclasses.dataclass
class LoopConfig:
    n_steps: int
    checkpoint_every: int = 50
    ckpt_dir: Optional[str] = None
    max_restarts: int = 3
    keep_checkpoints: int = 3


@dataclasses.dataclass
class LoopReport:
    final_state: Any
    steps_run: int
    restarts: int
    resumed_from: Optional[int]
    wall_time_s: float
    step_metrics: list


class PreemptionGuard:
    """Checkpoint-on-SIGTERM: cooperative preemption for managed clusters."""

    def __init__(self):
        self.requested = False
        self._prev = None

    def __enter__(self):
        def handler(signum, frame):
            self.requested = True
        try:
            self._prev = signal.signal(signal.SIGTERM, handler)
        except ValueError:  # non-main thread (tests)
            self._prev = None
        return self

    def __exit__(self, *exc):
        if self._prev is not None:
            signal.signal(signal.SIGTERM, self._prev)
        return False


def run_with_restarts(step_fn: Callable[[Any, int], tuple],
                      init_state: Any, loop: LoopConfig,
                      failure_injector: Optional[Callable[[int], None]] = None
                      ) -> LoopReport:
    """Run ``state, metrics = step_fn(state, step)`` for n_steps with
    checkpoint/restart.

    On an exception (real failure or injected), reloads the latest checkpoint
    and continues, up to ``max_restarts``. The state pytree must be
    checkpoint-restorable (arrays only).
    """
    t0 = time.monotonic()
    saver = (ckpt_lib.AsyncCheckpointer(loop.ckpt_dir, loop.keep_checkpoints)
             if loop.ckpt_dir else None)
    state = init_state
    start_step = 0
    resumed_from = None
    if loop.ckpt_dir:
        latest = ckpt_lib.latest_step(loop.ckpt_dir)
        if latest is not None:
            state = ckpt_lib.restore(loop.ckpt_dir, latest, init_state)
            start_step = latest
            resumed_from = latest
    restarts = 0
    metrics_log = []
    step = start_step
    with PreemptionGuard() as guard:
        while step < loop.n_steps:
            try:
                if failure_injector is not None:
                    failure_injector(step)
                state, metrics = step_fn(state, step)
                metrics_log.append(metrics)
                step += 1
                at_ckpt = loop.ckpt_dir and (
                    step % loop.checkpoint_every == 0 or step == loop.n_steps)
                if at_ckpt or (guard.requested and loop.ckpt_dir):
                    saver.save(step, state, extra={"step": step})
                if guard.requested:
                    break
            except Exception:
                restarts += 1
                if restarts > loop.max_restarts or not loop.ckpt_dir:
                    raise
                saver.wait()
                latest = ckpt_lib.latest_step(loop.ckpt_dir)
                if latest is None:
                    state, step = init_state, 0
                else:
                    state = ckpt_lib.restore(loop.ckpt_dir, latest, init_state)
                    step = latest
    if saver:
        saver.wait()
    return LoopReport(final_state=state, steps_run=step - start_step,
                      restarts=restarts, resumed_from=resumed_from,
                      wall_time_s=time.monotonic() - t0,
                      step_metrics=metrics_log)
