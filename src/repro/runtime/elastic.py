"""Elastic re-meshing: shrink/grow the data axis around failed slices.

The stage ("model") axis is a hard dependency ring — losing a stage chip
breaks the pipeline — so elasticity operates on the data axis: a failure
cordons the data row containing the chip, the mesh is rebuilt from surviving
rows, gangs are re-planned (same trials, smaller data axis) and training
resumes from the last checkpoint. Parameter shards re-place automatically
because shardings are derived from the new mesh, and the deterministic data
pipeline keeps gradients identical (global batch re-sharded, not re-sized).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import numpy as np

from repro.core.pipeline import EngineConfig
from repro.core import scheduler as sched


@dataclasses.dataclass(frozen=True)
class MeshHealth:
    """Which (pod, data) rows are alive. The stage axis is all-or-nothing."""

    alive_rows: tuple  # of (pod_idx, data_idx)
    n_pods: int
    n_data: int

    @classmethod
    def fresh(cls, n_pods: int, n_data: int):
        return cls(tuple((p, d) for p in range(n_pods) for d in range(n_data)),
                   n_pods, n_data)

    def cordon(self, pod: int, data_row: int) -> "MeshHealth":
        alive = tuple(r for r in self.alive_rows if r != (pod, data_row))
        if not alive:
            raise RuntimeError("no healthy rows remain")
        return dataclasses.replace(self, alive_rows=alive)

    @property
    def usable_data_rows(self) -> int:
        """Largest uniform data-axis size across pods (SPMD needs a box)."""
        per_pod = {}
        for p, d in self.alive_rows:
            per_pod.setdefault(p, 0)
            per_pod[p] += 1
        return min(per_pod.values())

    @property
    def usable_pods(self) -> int:
        return len({p for p, _ in self.alive_rows})


def rebuild_mesh(devices: Sequence, health: MeshHealth, n_stages: int,
                 multi_pod: bool):
    """Build the largest healthy box mesh from surviving devices."""
    n_data = health.usable_data_rows
    n_pods = health.usable_pods if multi_pod else 1
    need = n_pods * n_data * n_stages
    if len(devices) < need:
        raise RuntimeError(f"need {need} devices, have {len(devices)}")
    dev = np.asarray(devices[:need])
    if multi_pod:
        dev = dev.reshape(n_pods, n_data, n_stages)
        return jax.sharding.Mesh(dev, ("pod", "data", "model"))
    dev = dev.reshape(n_data, n_stages)
    return jax.sharding.Mesh(dev, ("data", "model"))


def shrink_engine(eng: EngineConfig, health: MeshHealth) -> EngineConfig:
    return dataclasses.replace(
        eng, data_size=health.usable_data_rows,
        pod_size=health.usable_pods if eng.pod_axis else 1)


def elastic_replan(gangs, base_eng: EngineConfig, arch_configs: dict,
                   seq_len: int, health: MeshHealth):
    """Scheduler hook: same trials, shrunken mesh."""
    lost = base_eng.data_size - health.usable_data_rows
    return sched.replan_after_failure(gangs, base_eng, arch_configs, seq_len,
                                      lost_data_rows=lost)
