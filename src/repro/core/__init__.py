from repro.core.partitioner import StagePlan, plan_stages  # noqa: F401
from repro.core.pipeline import (  # noqa: F401
    EngineConfig,
    init_trial_params,
    make_serve_step,
    make_train_step,
    param_pspecs,
    pipeline_train_loss,
)
from repro.core.scheduler import GangPlan, TrialSpec, plan_gangs  # noqa: F401
