"""Hydra orchestrator: search space → gangs → shard-parallel training →
model selection. The end-to-end system of the paper (Fig. 3) with Cerebro's
role played by ``core.trials``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.configs.base import ArchConfig
from repro.core import pipeline as pl
from repro.core.partitioner import plan_stages
from repro.core.scheduler import GangPlan, TrialSpec, plan_gangs
from repro.core.trials import TrialResult
from repro.data.pipeline import TrainBatches
from repro.models.layers import ModelOptions
from repro.obs.tracer import resolve
from repro.optim.adamw import AdamW
from repro.runtime.fault_tolerance import LoopConfig, run_with_restarts


@dataclasses.dataclass
class HydraConfig:
    seq_len: int
    steps: int
    eval_every: int = 0  # 0 = only at end
    checkpoint_every: int = 50
    ckpt_dir: Optional[str] = None
    seed: int = 0
    param_dtype: jnp.dtype = jnp.float32


class HydraRunner:
    """Runs one gang (same-arch trials) as a single shard-parallel program."""

    def __init__(self, cfg: ArchConfig, opts: ModelOptions, mesh,
                 hydra_cfg: HydraConfig, optimizer: Optional[AdamW] = None,
                 tracer=None):
        self.cfg, self.opts, self.mesh = cfg, opts, mesh
        self.hc = hydra_cfg
        self.optimizer = optimizer or AdamW(grad_clip=1.0)
        # gang/rung wall-clock spans for the obs timeline (NULL_TRACER when
        # off — span emission is two events per gang, never per step)
        self.trace = resolve(tracer)

    def _build(self, gang: GangPlan):
        eng = gang.engine
        plan = plan_stages(self.cfg, eng.n_stages)
        key = jax.random.PRNGKey(self.hc.seed)
        max_pos = self.hc.seq_len if self.cfg.rope == "learned" else 0
        params = pl.init_trial_params(self.cfg, eng, plan, key,
                                      dtype=self.hc.param_dtype,
                                      max_pos=max_pos)
        opt_state = self.optimizer.init(params)
        hparams = {
            "lr": jnp.asarray([t.lr for t in gang.trials], jnp.float32),
            "wd": jnp.asarray([t.weight_decay for t in gang.trials],
                              jnp.float32),
        }
        step_fn = pl.make_train_step(self.cfg, self.opts, eng, self.mesh,
                                     self.optimizer)
        return params, opt_state, hparams, step_fn

    def run_gang(self, gang: GangPlan, n_steps: Optional[int] = None
                 ) -> list[TrialResult]:
        eng = gang.engine
        n_steps = n_steps or self.hc.steps
        if self.trace.enabled:
            self.trace.span_begin("gang", arch=gang.arch,
                                  n_trials=eng.n_trials, steps=n_steps)
        params, opt_state, hparams, step_fn = self._build(gang)
        data = TrainBatches(self.cfg, eng, self.hc.seq_len,
                            seed=self.hc.seed)
        losses = np.zeros((eng.n_trials,), np.float64)

        def one_step(state, step):
            p, o = state
            batch = data.batch_for_step(step)
            p, o, metrics = step_fn(p, o, batch, hparams,
                                    jnp.asarray(step, jnp.int32))
            return (p, o), metrics

        # each gang owns a checkpoint subdirectory: restarts within one gang
        # resume exactly, but a later gang (another rung of successive
        # halving, a different K) can never restore a stale checkpoint whose
        # trial axis doesn't match its own parameter shapes
        ckpt_dir = self.hc.ckpt_dir
        if ckpt_dir is not None:
            tag = "|".join(t.tag or f"lr{t.lr:g}wd{t.weight_decay:g}s{t.seed}"
                           for t in gang.trials)
            digest = hashlib.md5(tag.encode()).hexdigest()[:8]
            ckpt_dir = os.path.join(
                ckpt_dir, f"{gang.arch}-k{eng.n_trials}-n{n_steps}-{digest}")
        report = run_with_restarts(
            one_step, (params, opt_state),
            LoopConfig(n_steps=n_steps,
                       checkpoint_every=self.hc.checkpoint_every,
                       ckpt_dir=ckpt_dir))
        data.close()
        params, opt_state = report.final_state
        if report.step_metrics:
            losses = np.asarray(report.step_metrics[-1]["loss"])
        # held-out evaluation: a fresh deterministic batch beyond train steps
        val = self.evaluate(gang, params, hparams, step=10_000_000)
        if self.trace.enabled:
            self.trace.span_end("gang", arch=gang.arch)
        return [TrialResult(spec=t, steps=n_steps,
                            train_loss=float(losses[i]),
                            val_loss=float(val[i]))
                for i, t in enumerate(gang.trials)]

    def evaluate(self, gang: GangPlan, params, hparams, step: int):
        """Per-trial validation loss on a held-out deterministic batch."""
        eng = gang.engine
        data = TrainBatches(self.cfg, eng, self.hc.seq_len,
                            seed=self.hc.seed + 999)
        batch = data.batch_for_step(step)
        data.close()
        pspecs = pl.param_pspecs(self.cfg, eng)
        bspecs = pl.batch_pspecs(self.cfg, eng, train=True)
        from jax.sharding import PartitionSpec as P

        def inner(p, b):
            loss_vec, _ = pl.pipeline_train_loss(self.cfg, self.opts, eng,
                                                 p, b)
            for ax in eng.dp_axes:
                loss_vec = jax.lax.pmean(loss_vec, ax)
            return loss_vec

        fn = jax.jit(shard_map(inner, mesh=self.mesh,
                                   in_specs=(pspecs, bspecs),
                                   out_specs=P(), check_vma=False))
        return np.asarray(fn(params, batch))


def run_model_selection(cfg: ArchConfig, opts: ModelOptions, mesh,
                        hydra_cfg: HydraConfig, trials: Sequence[TrialSpec],
                        base_eng: pl.EngineConfig,
                        strategy=None, tracer=None) -> dict:
    """Full Hydra workflow: plan gangs, train them shard-parallel, select.

    ``tracer`` (``repro.obs.Tracer``) wraps each successive-halving rung —
    every ``train_fn`` invocation — and each gang in wall-clock spans, so
    a search run exports the same Perfetto timeline as a serve run.

    Returns {"best": TrialResult, "all": [TrialResult...], "gangs": int}.
    """
    trace = resolve(tracer)
    runner = HydraRunner(cfg, opts, mesh, hydra_cfg, tracer=tracer)
    all_results: list[TrialResult] = []
    rung = [0]  # train_fn call index (a halving strategy calls it per rung)

    def train_fn(specs, n_steps):
        if trace.enabled:
            trace.span_begin("rung", label=rung[0], n_trials=len(specs),
                             steps=n_steps)
        gangs = plan_gangs(specs, base_eng, {cfg.name: cfg},
                           hydra_cfg.seq_len)
        out = []
        for g in gangs:
            out.extend(runner.run_gang(g, n_steps))
        all_results.extend(out)
        if trace.enabled:
            trace.span_end("rung", label=rung[0])
        rung[0] += 1
        return out

    if strategy is None:
        results = train_fn(list(trials), hydra_cfg.steps)
        best = min(results, key=lambda r: r.val_loss)
    else:
        best = strategy.run(list(trials), train_fn)
    return {"best": best, "all": all_results}
