"""Model-selection strategies (the Cerebro/Vizier/Tune layer of the paper).

Hydra pairs its shard-parallel executor with a selection system; this module
provides the search-space → trial-stream side: grid search, random search and
(asynchronous-style) successive halving, all operating on ``TrialSpec``s and
consuming per-trial validation losses from the gang runner.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import random
from typing import Optional, Sequence

from repro.core.scheduler import TrialSpec


@dataclasses.dataclass
class TrialResult:
    spec: TrialSpec
    steps: int
    train_loss: float
    val_loss: float


def grid_search(arch: str, lrs: Sequence[float],
                weight_decays: Sequence[float] = (0.0,),
                seeds: Sequence[int] = (0,)) -> list[TrialSpec]:
    out = []
    for lr, wd, seed in itertools.product(lrs, weight_decays, seeds):
        out.append(TrialSpec(arch=arch, lr=lr, weight_decay=wd, seed=seed,
                             tag=f"lr{lr:g}-wd{wd:g}-s{seed}"))
    return out


def random_search(arch: str, n: int, lr_range=(1e-5, 1e-2),
                  wd_range=(0.0, 0.1), seed: int = 0) -> list[TrialSpec]:
    rng = random.Random(seed)
    out = []
    for i in range(n):
        lr = math.exp(rng.uniform(math.log(lr_range[0]), math.log(lr_range[1])))
        wd = rng.uniform(*wd_range)
        out.append(TrialSpec(arch=arch, lr=lr, weight_decay=wd, seed=i,
                             tag=f"rand{i}"))
    return out


@dataclasses.dataclass
class SuccessiveHalving:
    """Synchronous successive halving over Hydra gangs.

    Rung r trains the surviving trials for ``base_steps * eta**r`` steps, then
    keeps the top 1/eta by validation loss. Because Hydra trains a whole rung
    as one shard-parallel gang, a rung costs roughly one model's time instead
    of K models' time — this is the paper's throughput claim applied to the
    selection loop itself.
    """

    base_steps: int = 50
    eta: int = 2
    max_rungs: int = 3

    def rung_steps(self, rung: int) -> int:
        return self.base_steps * (self.eta ** rung)

    def survivors(self, results: Sequence[TrialResult]) -> list[TrialSpec]:
        keep = max(1, len(results) // self.eta)
        ranked = sorted(results, key=lambda r: r.val_loss)
        return [r.spec for r in ranked[:keep]]

    def run(self, trials: Sequence[TrialSpec], train_fn) -> TrialResult:
        """train_fn(trials, n_steps) -> list[TrialResult] (one gang run)."""
        alive = list(trials)
        last: Optional[list[TrialResult]] = None
        for rung in range(self.max_rungs):
            last = train_fn(alive, self.rung_steps(rung))
            alive = self.survivors(last)
            if len(alive) == 1:
                break
        final = [r for r in last if r.spec in alive]
        return min(final, key=lambda r: r.val_loss)
