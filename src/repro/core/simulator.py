"""Discrete-event simulator of multi-model training schedules.

Reproduces the paper's Figure 2 comparison — task parallelism vs model
parallelism vs Hydra's shard parallelism — as *measured makespans and device
utilizations* of an event-driven executor, not just closed-form formulas (the
formulas are asserted against the simulator in tests).

Model (matches the paper's setting):
  * K models, each a chain of S shards; a device holds one shard per model
    (device d holds shard d of every model it serves).
  * A shard task (model k, shard s, microbatch m, direction) is ready when its
    predecessor finished; forward chains s=0..S-1, backward chains back.
  * Backward work costs ``bwd_ratio`` × forward work (default 2).
  * Task parallelism: each model trains alone on one device (needs the model
    to fit — the regime the paper says breaks for big models).
  * Model parallelism: models run one at a time, sharded over all devices.
  * Shard parallelism: all models' shards stream through the device ring.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional


@dataclasses.dataclass(frozen=True)
class SimResult:
    makespan: float
    utilization: float  # busy-time / (devices × makespan)
    per_device_busy: tuple

    def speedup_over(self, other: "SimResult") -> float:
        return other.makespan / self.makespan


def _simulate(task_graph, n_devices: int) -> SimResult:
    """Generic list-scheduler DES: task = (device, duration, deps...)."""
    n_tasks = len(task_graph)
    indeg = [0] * n_tasks
    succ: list[list[int]] = [[] for _ in range(n_tasks)]
    for i, (_, _, deps) in enumerate(task_graph):
        indeg[i] = len(deps)
        for d in deps:
            succ[d].append(i)
    dev_free = [0.0] * n_devices
    busy = [0.0] * n_devices
    ready: list[tuple[float, int]] = []  # (earliest_start, task)
    task_ready_time = [0.0] * n_tasks
    for i in range(n_tasks):
        if indeg[i] == 0:
            heapq.heappush(ready, (0.0, i))
    finish = [0.0] * n_tasks
    pending = n_tasks
    while ready:
        est, i = heapq.heappop(ready)
        dev, dur, _ = task_graph[i]
        start = max(est, dev_free[dev])
        end = start + dur
        dev_free[dev] = end
        busy[dev] += dur
        finish[i] = end
        pending -= 1
        for j in succ[i]:
            indeg[j] -= 1
            task_ready_time[j] = max(task_ready_time[j], end)
            if indeg[j] == 0:
                heapq.heappush(ready, (task_ready_time[j], j))
    if pending:
        raise RuntimeError("cyclic task graph")
    makespan = max(finish) if finish else 0.0
    util = sum(busy) / (n_devices * makespan) if makespan else 0.0
    return SimResult(makespan, util, tuple(busy))


def simulate_shard_parallel(n_models: int, n_shards: int,
                            n_microbatches: int = 1, fwd_cost: float = 1.0,
                            bwd_ratio: float = 2.0) -> SimResult:
    """Hydra: K models × M microbatches stream through S shard-devices."""
    tasks = []
    idx = {}
    for k in range(n_models):
        for m in range(n_microbatches):
            for s in range(n_shards):
                deps = []
                if s > 0:
                    deps.append(idx[(k, m, s - 1, "f")])
                idx[(k, m, s, "f")] = len(tasks)
                tasks.append((s, fwd_cost, deps))
            for s in reversed(range(n_shards)):
                deps = [idx[(k, m, s + 1, "b")] if s < n_shards - 1
                        else idx[(k, m, n_shards - 1, "f")]]
                idx[(k, m, s, "b")] = len(tasks)
                tasks.append((s, fwd_cost * bwd_ratio, deps))
    return _simulate(tasks, n_shards)


def simulate_model_parallel(n_models: int, n_shards: int,
                            n_microbatches: int = 1, fwd_cost: float = 1.0,
                            bwd_ratio: float = 2.0,
                            pipelined: bool = False) -> SimResult:
    """Model parallelism baselines, one model at a time over all devices.

    ``pipelined=False`` (default) is the paper's *traditional* model
    parallelism (Fig. 1): strictly sequential microbatches, utilization 1/S.
    ``pipelined=True`` is the stronger GPipe-style baseline — microbatches of
    one model pipeline, but each model still pays its own fill/drain bubble.
    """
    tasks = []
    prev_model_end: Optional[int] = None
    for k in range(n_models):
        idx = {}
        for m in range(n_microbatches):
            for s in range(n_shards):
                deps = []
                if s > 0:
                    deps.append(idx[(m, s - 1, "f")])
                elif m > 0:
                    # pipelined: next microbatch may enter as soon as stage 0
                    # frees; sequential: only after the previous microbatch's
                    # backward fully completes (the paper's Fig. 1 timeline)
                    deps.append(idx[(m - 1, 0, "f")] if pipelined
                                else idx[(m - 1, 0, "b")])
                if s == 0 and m == 0 and prev_model_end is not None:
                    deps.append(prev_model_end)
                idx[(m, s, "f")] = len(tasks)
                tasks.append((s, fwd_cost, deps))
            for s in reversed(range(n_shards)):
                deps = [idx[(m, s + 1, "b")] if s < n_shards - 1
                        else idx[(m, n_shards - 1, "f")]]
                idx[(m, s, "b")] = len(tasks)
                tasks.append((s, fwd_cost * bwd_ratio, deps))
        prev_model_end = idx[(n_microbatches - 1, 0, "b")]
    return _simulate(tasks, n_shards)


def simulate_task_parallel(n_models: int, n_devices: int,
                           n_shards: int, n_microbatches: int = 1,
                           fwd_cost: float = 1.0,
                           bwd_ratio: float = 2.0) -> SimResult:
    """Task parallelism: each model whole on one device (models must fit)."""
    tasks = []
    per_model = n_shards * n_microbatches * fwd_cost * (1 + bwd_ratio)
    for k in range(n_models):
        dev = k % n_devices
        deps = [len(tasks) - 1] if k >= n_devices else []
        tasks.append((dev, per_model, deps))
    return _simulate(tasks, n_devices)


def theoretical_shard_parallel_makespan(n_models: int, n_shards: int,
                                        n_microbatches: int = 1,
                                        fwd_cost: float = 1.0,
                                        bwd_ratio: float = 2.0) -> float:
    """Closed form used by the scheduler's what-if planning: steady-state
    work + fill/drain bubble. Asserted ≈ simulator in tests."""
    slots = n_models * n_microbatches
    per_slot = fwd_cost * (1 + bwd_ratio)
    return slots * per_slot + (n_shards - 1) * per_slot


def figure2_table(n_shards: int = 8, n_models_list=(1, 2, 4, 8, 16),
                  n_microbatches: int = 16) -> list[dict]:
    """The paper's Fig. 2 as numbers: speedup of shard parallelism.

    ``n_microbatches`` models the per-step batch stream (training runs many
    microbatches per model, so the fill/drain bubble amortizes — M=16 gives
    the steady-state regime the paper's figure depicts)."""
    rows = []
    for k in n_models_list:
        sp = simulate_shard_parallel(k, n_shards, n_microbatches)
        mp = simulate_model_parallel(k, n_shards, n_microbatches)
        gp = simulate_model_parallel(k, n_shards, n_microbatches,
                                     pipelined=True)
        tp = simulate_task_parallel(k, n_shards, n_shards, n_microbatches)
        rows.append({
            "n_models": k,
            "n_shards": n_shards,
            "shard_makespan": sp.makespan,
            "model_makespan": mp.makespan,
            "gpipe_makespan": gp.makespan,
            "task_makespan": tp.makespan,
            "shard_util": sp.utilization,
            "model_util": mp.utilization,
            "gpipe_util": gp.utilization,
            "task_util": tp.utilization,
            "speedup_vs_model_parallel": sp.speedup_over(mp) if sp.makespan else 0,
            "speedup_vs_gpipe": sp.speedup_over(gp) if sp.makespan else 0,
            "speedup_vs_task_parallel": sp.speedup_over(tp) if sp.makespan else 0,
        })
    return rows
