"""Hydra's shard-parallel task scheduler (the paper's "scheduler" box).

On TPU the *within-gang* schedule is compiled (static round-robin — see
pipeline.py); this module handles everything the compiler can't:

  * **capacity planning** — how many concurrent trials K fit per chip given
    the HBM budget (params + optimizer + pipeline activation stash + caches);
  * **gang planning** — grouping a trial population (possibly heterogeneous
    architectures) into same-architecture gangs of size ≤ K_max and choosing
    microbatch counts so the pipeline bubble fraction meets a target;
  * **failure / elasticity policy** — re-planning gangs around cordoned mesh
    slices and shrunken data axes (used by runtime/elastic.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.partitioner import plan_stages
from repro.core.pipeline import EngineConfig

# TPU v5e (the deployment target; see EXPERIMENTS.md §Roofline)
HBM_BYTES_PER_CHIP = 16 * 1024 ** 3
HBM_BUDGET_FRACTION = 0.9  # leave headroom for XLA scratch


@dataclasses.dataclass(frozen=True)
class TrialSpec:
    """One model-selection trial (the task-parallel unit of the paper)."""

    arch: str
    lr: float
    weight_decay: float = 0.0
    seed: int = 0
    tag: str = ""


@dataclasses.dataclass(frozen=True)
class MemoryEstimate:
    params_bytes: int
    opt_bytes: int
    act_bytes: int
    cache_bytes: int

    @property
    def total(self) -> int:
        return self.params_bytes + self.opt_bytes + self.act_bytes \
            + self.cache_bytes


def per_chip_bytes(cfg: ArchConfig, eng: EngineConfig, seq_len: int,
                   train: bool, param_bytes: int = 2,
                   opt_bytes_per_param: int = 12) -> MemoryEstimate:
    """Per-chip HBM model for ONE trial under the engine config.

    Stage sharding divides layer params by n_stages; FSDP further divides by
    data_size. The activation stash covers the in-flight pipeline slots
    (n_ticks live stage-inputs with remat). Optimizer = fp32 m + v + master.
    """
    plan = plan_stages(cfg, eng.n_stages)
    layer_p = cfg.layer_param_count() * plan.layers_per_stage
    if eng.fsdp:
        layer_p = math.ceil(layer_p / eng.data_size)
    vocab_p = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    vocab_p = math.ceil(vocab_p / (eng.n_stages if eng.vocab_parallel else 1))
    shared_p = cfg.shared_block_param_count()
    n_params_local = layer_p + vocab_p + shared_p + cfg.d_model
    params_b = n_params_local * param_bytes
    opt_b = n_params_local * opt_bytes_per_param if train else 0
    if train:
        # pipeline stash: one stage-input per in-flight tick (remat policy).
        # Empirically (XLA buffer dump, EXPERIMENTS §Perf) the backward also
        # materializes an fp32 convert of the whole stash hoisted out of the
        # loop, so budget bf16 + fp32 = 6 bytes per element.
        act_b = eng.n_ticks * eng.microbatch * seq_len * cfg.d_model * 6
        # transient working set: gathered layer weights (×2 for grad), attn
        # carries, fp32 grad buffers of one layer
        act_b += 3 * cfg.layer_param_count() * 4
        act_b += 8 * eng.microbatch * min(seq_len, 4096) * cfg.d_model * 4
        cache_b = 0
    else:
        act_b = 4 * eng.microbatch * min(seq_len, 4096) * cfg.d_model * 4
        cache_b = _cache_bytes_per_chip(cfg, eng, seq_len)
    return MemoryEstimate(params_b, opt_b, act_b, cache_b)


def kv_token_bytes_per_chip(cfg: ArchConfig, eng: EngineConfig) -> int:
    """K+V bytes ONE cached token costs across this chip's layer slice
    (2 tensors × the engine's cache dtype)."""
    plan = plan_stages(cfg, eng.n_stages)
    itemsize = jnp.dtype(eng.cache_dtype).itemsize
    return (cfg.n_kv_heads * cfg.head_dim * 2 * itemsize
            * plan.layers_per_stage)


def _cache_bytes_per_chip(cfg: ArchConfig, eng: EngineConfig,
                          seq_len: int) -> int:
    if eng.paged:
        # the persistent cache is the block pool, not slots × max_seq strips
        dp = 1 if eng.batch_replicated else eng.data_size * eng.pod_size
        local_blocks = eng.n_blocks // max(dp, 1)
        return (local_blocks * eng.block_size
                * kv_token_bytes_per_chip(cfg, eng))
    plan = plan_stages(cfg, eng.n_stages)
    b_local = eng.microbatch * eng.n_microbatches
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        itemsize = jnp.dtype(eng.cache_dtype).itemsize
        per_layer = b_local * di * s.d_state * 4  # fp32 state
        per_layer += b_local * (s.d_conv - 1) * di * itemsize
        total = per_layer * plan.layers_per_stage
        if cfg.hybrid is not None:
            w = min(seq_len, eng.window) if eng.window else seq_len
            total += (b_local * w * cfg.n_kv_heads * cfg.head_dim * 2
                      * itemsize)
        return total
    return b_local * seq_len * kv_token_bytes_per_chip(cfg, eng)


def max_concurrent_trials(cfg: ArchConfig, eng: EngineConfig, seq_len: int,
                          train: bool = True) -> int:
    """K_max: how many trials fit per chip (the paper's memory ceiling)."""
    budget = HBM_BYTES_PER_CHIP * HBM_BUDGET_FRACTION
    one = per_chip_bytes(cfg, dataclasses.replace(eng, n_trials=1), seq_len,
                         train).total
    return max(1, int(budget // max(one, 1)))


# ---------------------------------------------------------------------------
# Serving capacity planning (continuous-batching engine)
# ---------------------------------------------------------------------------


def plan_serve_capacity(cfg: ArchConfig, base_eng: EngineConfig,
                        max_seq: int, target_bubble: float = 0.25,
                        max_slots: int = 64, paged: bool = False,
                        expected_seq: Optional[int] = None,
                        block_size: int = 16,
                        hbm_bytes: Optional[int] = None,
                        budget_fraction: float = HBM_BUDGET_FRACTION,
                        mix: Optional[Sequence[tuple]] = None,
                        hit_rate: float = 0.0,
                        overcommit: float = 1.0,
                        host_blocks: int = 0,
                        ) -> EngineConfig:
    """Choose the serving slot grid for one model — or a co-serving gang.

    ``mix`` sizes the grid for a *traffic mix* across a K-variant gang: one
    ``(arrival_weight, expected_seq)`` pair per co-served arch. The returned
    config then carries ``n_trials = len(mix)`` (trial row k serves arch k)
    and every per-trial cost — params, dense strips, paged pools — is
    multiplied by K. ``mix=None`` is the single-arch plan (K=1,
    ``expected_seq`` as the lone expectation).

    Dense path: serving is forward-only, so ``per_chip_bytes(train=False)``
    applies — the KV/SSM cache at ``max_seq`` is the marginal HBM cost per
    slot (admission is by *worst case*: every cell reserves a full strip).
    Start from the pipeline-bubble target ((S-1)/(K·M+S-1) <= target —
    more slots = more concurrent requests = smaller bubble, Hydra's
    slot-filling insight applied to serving), then shrink M until the cache
    fits the budget.

    Paged path (``paged=True``): the leftover budget becomes one block pool
    per (chip, trial), and M is sized so the pools back K × M × microbatch
    rows at their arrival-weighted *expected* lengths — admission by
    expectation instead of worst case, which is where the capacity win over
    the dense plan comes from. Each trial's pool is an equal slice (the
    cache leaf is uniform over K); arches whose weighted demand
    ``K · w_k · expected_k`` exceeds the slice lean on the batcher's
    per-arch backpressure at runtime. The returned config carries
    ``n_blocks`` (per trial) / ``block_size``; the runtime batcher keeps the
    plan preemption-free by committing each admitted request's exact block
    need against its (trial, shard) partition and deferring that arch's
    admission when it would not fit (overcommit headroom is a batcher knob,
    see serve/paging.py).

    ``hit_rate`` (paged only) is the expected fraction of prompt+generation
    tokens served from shared radix-cached blocks (serve/prefix_cache.py):
    a cached block is resident once no matter how many concurrent requests
    read it, so each row's expected *new*-block demand shrinks by the hit
    rate and the same pool backs proportionally more slots. Plan with the
    traffic's measured prefix redundancy; the runtime batcher still commits
    exact per-request (non-cached) needs, so an optimistic hit_rate degrades
    to deferred admission, never to preemption.

    ``overcommit`` (paged only) widens the planned grid past the pool's
    expected-demand capacity by the same factor the runtime batcher admits
    past it: above 1.0 the engine trades occasional retraction (preemptive
    swap-out/recompute of the youngest request) for higher steady-state
    occupancy on bursty traces. ``host_blocks`` sizes the per-partition
    host spill tier carried into the returned config — it extends prefix
    retention and absorbs retraction payloads (cheap host DRAM), but backs
    no compute, so it never widens the grid itself.
    """
    if not 0.0 <= hit_rate < 1.0:
        raise ValueError(f"hit_rate must be in [0, 1), got {hit_rate}")
    if overcommit < 1.0:
        raise ValueError(f"planning overcommit must be >= 1.0 (the batcher "
                         f"accepts < 1.0 as a runtime safety margin, but a "
                         f"grid planned below capacity is dead weight), "
                         f"got {overcommit}")
    if host_blocks < 0:
        raise ValueError(f"host_blocks must be >= 0, got {host_blocks}")
    if (overcommit > 1.0 or host_blocks > 0) and not paged:
        raise ValueError("overcommit > 1.0 and host_blocks require "
                         "paged=True (dense strips cannot be retracted or "
                         "spilled)")
    budget = (HBM_BYTES_PER_CHIP if hbm_bytes is None
              else hbm_bytes) * budget_fraction
    if mix is not None:
        if not mix or any(w < 0 for w, _ in mix) \
                or sum(w for w, _ in mix) <= 0:
            raise ValueError(f"mix must be non-empty (weight, expected_seq) "
                             f"pairs with positive total weight, got {mix}")
        k_trials = len(mix)
        w_total = sum(w for w, _ in mix)
        # per-row expected demand of trial k, scaled by its arrival share
        # (uniform weights -> demand_k = expected_k)
        demands = [min(max(int(e), 1), max_seq) * (w * k_trials / w_total)
                   for w, e in mix]
    else:
        k_trials = 1
        demands = [min(max(expected_seq or max_seq // 2, 1), max_seq)]
    s = base_eng.n_stages
    if s > 1:
        m_bubble = math.ceil((s - 1) * (1.0 - target_bubble)
                             / max(target_bubble * k_trials, 1e-9))
    else:
        m_bubble = 1
    if paged:
        eng = dataclasses.replace(base_eng, n_trials=k_trials,
                                  max_seq=max_seq, paged=True,
                                  block_size=block_size, n_blocks=0,
                                  n_microbatches=1)
        est = per_chip_bytes(cfg, dataclasses.replace(eng, n_trials=1),
                             max_seq, train=False)
        # act_bytes is the per-tick transient working set and does NOT scale
        # with K: the serve scan advances one slot per stage per tick, so K
        # only lengthens the scan (K·M+S−1 ticks), never widens a tick
        fixed = (est.params_bytes + est.opt_bytes) * k_trials + est.act_bytes
        token_b = kv_token_bytes_per_chip(cfg, eng)
        dp = 1 if eng.batch_replicated else eng.data_size * eng.pod_size
        # (ceil-div mirrors serve/paging.py::blocks_for; core/ stays below
        # serve/ in the layering so it is not imported here)
        per_row = -(-max_seq // block_size)
        # floor: every (trial, shard) partition must back a full max_seq
        # request, or the batcher would hard-reject in-spec traffic at
        # enqueue time. local_blocks is per chip PER TRIAL.
        local_blocks = max(
            int(budget - fixed) // (token_b * block_size * k_trials),
            per_row)
        # prefix sharing: hit tokens ride on blocks resident once per
        # partition, so only (1 - hit_rate) of each row's tokens demand
        # fresh blocks
        mean_demand = max(sum(demands) / k_trials * (1.0 - hit_rate), 1.0)
        # overcommit admits past the pool by the same factor at runtime
        # (retraction absorbs the tail), so the planned grid widens with it
        m_cap = int(local_blocks * block_size * overcommit
                    // (mean_demand * eng.microbatch))
        m = min(max_slots, max(1, m_cap))
        # blocks beyond the capped grid's worst case are dead weight (every
        # cell fully backed at max_seq) — return them to the budget
        local_blocks = min(local_blocks, max(eng.microbatch * m, 1) * per_row)
        return dataclasses.replace(eng, n_microbatches=m,
                                   n_blocks=local_blocks * dp,
                                   host_blocks=host_blocks)
    m = min(max(m_bubble, base_eng.n_microbatches, 1), max_slots)
    eng = dataclasses.replace(base_eng, n_trials=k_trials, n_microbatches=m,
                              max_seq=max_seq)

    def total(e):
        one = per_chip_bytes(cfg, dataclasses.replace(e, n_trials=1),
                             max_seq, train=False)
        return ((one.params_bytes + one.opt_bytes + one.cache_bytes)
                * k_trials + one.act_bytes)

    while total(eng) > budget and eng.n_microbatches > 1:
        eng = dataclasses.replace(eng, n_microbatches=eng.n_microbatches - 1)
    return eng


# ---------------------------------------------------------------------------
# Gang planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GangPlan:
    """A set of same-architecture trials trained in one SPMD program."""

    arch: str
    trials: tuple  # TrialSpec...
    engine: EngineConfig

    @property
    def bubble_fraction(self) -> float:
        return self.engine.bubble_fraction


def plan_gangs(trials: Sequence[TrialSpec], base_eng: EngineConfig,
               arch_configs: dict, seq_len: int,
               target_bubble: float = 0.10,
               train: bool = True) -> list[GangPlan]:
    """Greedy gang former: group by architecture, split into capacity-bounded
    gangs, and size microbatch counts so each gang's bubble fraction meets
    ``target_bubble`` when memory allows.

    The paper's key scheduling claim (utilization → 1) is exactly the bubble
    fraction (S−1)/(K·M+S−1) → 0; this planner drives it below the target by
    raising K (more trials per gang) first — the Hydra move — and M second.
    """
    by_arch: dict[str, list[TrialSpec]] = {}
    for t in trials:
        by_arch.setdefault(t.arch, []).append(t)

    gangs = []
    for arch, ts in by_arch.items():
        cfg = arch_configs[arch]
        k_max = max_concurrent_trials(cfg, base_eng, seq_len, train)
        i = 0
        while i < len(ts):
            k = min(k_max, len(ts) - i)
            # choose M so bubble <= target: (S-1)/(K*M+S-1) <= target
            s = base_eng.n_stages
            m_needed = max(1, math.ceil(
                (s - 1) * (1 - target_bubble) / (target_bubble * k)))
            eng = dataclasses.replace(base_eng, n_trials=k,
                                      n_microbatches=m_needed)
            # shrink M if memory no longer fits
            while (per_chip_bytes(cfg, eng, seq_len, train).total * k
                   > HBM_BYTES_PER_CHIP * HBM_BUDGET_FRACTION
                   and eng.n_microbatches > 1):
                eng = dataclasses.replace(
                    eng, n_microbatches=eng.n_microbatches - 1)
            gangs.append(GangPlan(arch=arch, trials=tuple(ts[i:i + k]),
                                  engine=eng))
            i += k
    return gangs


# ---------------------------------------------------------------------------
# Failure / straggler policy (used by runtime/elastic.py + simulator)
# ---------------------------------------------------------------------------


def replan_after_failure(gangs: list[GangPlan], base_eng: EngineConfig,
                         arch_configs: dict, seq_len: int,
                         lost_data_rows: int) -> list[GangPlan]:
    """Shrink the data axis by the cordoned rows and re-form gangs.

    A failed chip cordons its entire data row (the stage axis is a hard
    dependency ring; the data axis is the elastic one). Trials keep their
    identity — training resumes from the last checkpoint with a smaller
    data axis, which changes throughput but not gradients (global batch is
    re-sharded, not re-sized).
    """
    new_data = base_eng.data_size - lost_data_rows
    if new_data < 1:
        raise RuntimeError("mesh lost all data rows; cannot re-plan")
    shrunk = dataclasses.replace(base_eng, data_size=new_data)
    trials = [t for g in gangs for t in g.trials]
    return plan_gangs(trials, shrunk, arch_configs, seq_len)
