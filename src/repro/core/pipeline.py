"""Hydra's shard-parallel execution engine.

The paper's core idea — run *shards of K independent models* concurrently so a
device idled by one model's sequential dependency works on another model — is
compiled here into a single SPMD program:

  * the ``model`` mesh axis holds pipeline *stages* (= the paper's shards);
  * the slot stream interleaves (trial k, microbatch m) pairs round-robin;
  * one ``lax.scan`` over ticks advances every stage one slot per tick, with
    activations hopping stage→stage via ``lax.ppermute`` over the ICI ring;
  * embedding and LM head are **vocab-parallel over the stage axis** (tokens
    are replicated across stages, so a masked-local-gather + psum is exact and
    the head matmul is split S ways instead of idling S−1 stages);
  * gradients come from ``jax.grad`` *through* the scanned pipeline — AD
    reverses the ppermute schedule automatically, so each trial's gradient is
    exactly the unpipelined gradient (paper desideratum D3).

Per-trial optimizer updates (vmapped hyperparameters over the K axis) and the
data/pod-axis gradient reductions also live inside the shard_map so every
collective is explicit and visible to the roofline analyzer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import shard_map
from repro.configs.base import ArchConfig
from repro.core.partitioner import StagePlan, plan_stages
from repro.models import blocks as BLK
from repro.models import lm
from repro.models.layers import ModelOptions


# ---------------------------------------------------------------------------
# Engine configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static configuration of one Hydra gang (same-architecture trials).

    In serving, the K trial rows double as the *co-serving* axis: each row
    holds one model variant's weights and caches, and the serve engine routes
    per-arch request streams into the matching rows (see repro/serve/).
    """

    n_trials: int  # K — concurrent models (the paper's task-parallel level)
    n_microbatches: int  # M — slots per trial per step
    microbatch: int  # per-(data×pod)-replica microbatch size
    n_stages: int  # size of the stage ("model") mesh axis
    data_size: int = 1  # size of the data axis
    pod_size: int = 1  # size of the pod axis (1 = single pod)
    stage_axis: str = "model"
    data_axis: str = "data"
    pod_axis: Optional[str] = None
    fsdp: bool = False  # ZeRO-style: shard layer weights over data axis
    vocab_parallel: bool = True
    batch_replicated: bool = False  # batch too small to shard (long_500k)
    window: int = 0  # sliding window for attention (long-context serving)
    max_seq: int = 0  # cache length for serving
    cache_dtype: Any = jnp.bfloat16
    # --- paged KV-cache (serving only; see repro/serve/paging.py) ----------
    paged: bool = False  # serve KV in a shared block pool instead of dense
    # per-slot max_seq strips (attention families only)
    block_size: int = 16  # tokens per block
    n_blocks: int = 0  # pool size PER TRIAL (the paged cache leaf carries a
    # leading K axis — each co-served variant owns its own pool); rows sharded
    # over the data/pod axes each own an equal pool slice (n_blocks /
    # dp_degree blocks per shard per trial)
    host_blocks: int = 0  # host-memory spill tier PER POOL PARTITION (serve
    # BlockStore): evicted prefix-cache blocks and retracted requests' KV
    # swap out here instead of being destroyed; 0 = no host tier
    # --- §Perf knobs (baseline: all off/default) ---------------------------
    skip_bubbles: bool = False  # cond-skip fill/drain ticks (compute+gathers;
    # safe: validity is uniform over every axis the inner collectives span)
    prefill_chunks: int = 1  # >1: chunked prefill — sequence chunks become
    # extra pipeline slots (Hydra's slot-filling applied within one request);
    # chunk c attends to the cache written by chunks < c (mode="append")
    layer_remat: bool = True  # inner per-layer checkpoint (False = tick-level
    # remat only: one fewer weight-gather round in backward)

    @property
    def n_slots(self) -> int:
        return self.n_trials * self.n_microbatches

    @property
    def n_ticks(self) -> int:
        return self.n_slots + self.n_stages - 1

    @property
    def dp_axes(self):
        """Axes carrying data parallelism (batch sharding + grad reduction)."""
        if self.pod_axis is not None:
            return (self.pod_axis, self.data_axis)
        return (self.data_axis,)

    @property
    def bubble_fraction(self) -> float:
        return (self.n_stages - 1) / self.n_ticks

    @property
    def cache_groups(self) -> int:
        """Distinct caches in serving: chunked prefill shares one cache per
        request group across its sequence-chunk slots."""
        if self.prefill_chunks > 1:
            return self.n_microbatches // self.prefill_chunks
        return self.n_microbatches

    def padded_vocab(self, vocab: int) -> int:
        s = self.n_stages
        return -(-vocab // s) * s


# ---------------------------------------------------------------------------
# Parameter layout: trial-stacked, stage-sharded (+ optional FSDP)
# ---------------------------------------------------------------------------


def _fsdp_dim(path_leaf_shape, data_size: int) -> Optional[int]:
    """Pick the dim (of the unstacked layer leaf) to shard over the data axis.

    Prefer the first matrix dim divisible by the data-axis size; vectors stay
    replicated.
    """
    if len(path_leaf_shape) < 2:
        return None
    for d, size in enumerate(path_leaf_shape):
        if size % data_size == 0 and size >= data_size:
            return d
    return None


def trial_params_struct(cfg: ArchConfig, eng: EngineConfig, plan: StagePlan,
                        dtype=jnp.bfloat16, max_pos: int = 0):
    """ShapeDtypeStructs of the trial-stacked parameter pytree (dry-run)."""
    one = jax.eval_shape(
        lambda k: lm.init_params(cfg, k, dtype=dtype, max_pos=max_pos,
                                 n_layers=plan.padded_layers),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    vpad = eng.padded_vocab(cfg.vocab_size)

    def fix(path, s):
        shape = (eng.n_trials,) + s.shape
        name = "/".join(str(p.key) if hasattr(p, "key") else str(p)
                        for p in path)
        if name == "embed/tok":
            shape = (eng.n_trials, vpad, cfg.d_model)
        if name == "head":
            shape = (eng.n_trials, cfg.d_model, vpad)
        return jax.ShapeDtypeStruct(shape, s.dtype)

    return jax.tree_util.tree_map_with_path(fix, one)


def init_trial_params(cfg: ArchConfig, eng: EngineConfig, plan: StagePlan,
                      key, dtype=jnp.float32, max_pos: int = 0):
    """Materialize K trials' parameters (stacked on a leading K axis)."""
    keys = jax.random.split(key, eng.n_trials)
    params = jax.vmap(
        lambda k: lm.init_params(cfg, k, dtype=dtype, max_pos=max_pos,
                                 n_layers=plan.padded_layers))(keys)
    vpad = eng.padded_vocab(cfg.vocab_size)
    if vpad != cfg.vocab_size:
        pad = vpad - cfg.vocab_size
        params["embed"]["tok"] = jnp.pad(
            params["embed"]["tok"], ((0, 0), (0, pad), (0, 0)))
        if "head" in params:
            params["head"] = jnp.pad(params["head"], ((0, 0), (0, 0), (0, pad)))
    return params


def param_pspecs(cfg: ArchConfig, eng: EngineConfig):
    """PartitionSpec pytree for the trial-stacked params.

    layers/*   : (K, Lp, ...)   -> P(None, stage, [fsdp-dim over data])
    embed/tok  : (K, Vp, D)     -> P(None, stage, None)  [vocab-parallel]
    embed/pos  : (K, maxpos, D) -> P(None, stage, None)  [position-parallel]
    head       : (K, D, Vp)     -> P(None, None, stage)
    final_norm : replicated ; shared/* : replicated (grads psum'd over stage)
    """
    st, da = eng.stage_axis, eng.data_axis
    plan = plan_stages(cfg, eng.n_stages)
    struct = trial_params_struct(cfg, eng, plan)

    def spec(path, leaf):
        name = "/".join(str(p.key) if hasattr(p, "key") else str(p)
                        for p in path)
        if name.startswith("layers/"):
            rest = [None] * (leaf.ndim - 2)
            if eng.fsdp:
                d = _fsdp_dim(leaf.shape[2:], eng.data_size)
                if d is not None:
                    rest[d] = da
            return P(None, st, *rest)
        if name == "embed/tok" or name == "embed/pos":
            if eng.vocab_parallel:
                return P(None, st, *([None] * (leaf.ndim - 2)))
            return P(*([None] * leaf.ndim))
        if name == "head":
            if eng.vocab_parallel:
                return P(None, None, st)
            return P(*([None] * leaf.ndim))
        return P(*([None] * leaf.ndim))  # final_norm, shared/*

    return jax.tree_util.tree_map_with_path(spec, struct)


# ---------------------------------------------------------------------------
# Vocab-parallel embedding / loss / sampling (stage-axis collectives)
# ---------------------------------------------------------------------------


def _stage_info(eng: EngineConfig):
    s_idx = lax.axis_index(eng.stage_axis)
    return s_idx, eng.n_stages


def vp_embed(cfg: ArchConfig, eng: EngineConfig, embed_local, tokens,
             positions=None, compute_dtype=jnp.float32):
    """Vocab-parallel embedding: masked local gather + psum over stages.

    Tokens are replicated across the stage axis, so each stage gathers the
    rows it owns and the psum reconstitutes the full embedding exactly.
    """
    s_idx, n_stages = _stage_info(eng)
    tok_tab = embed_local["tok"]  # (V_pad/S, D)
    v_s = tok_tab.shape[0]
    local = tokens - s_idx * v_s
    valid = (local >= 0) & (local < v_s)
    rows = jnp.take(tok_tab, jnp.clip(local, 0, v_s - 1), axis=0)
    part = jnp.where(valid[..., None], rows, 0).astype(compute_dtype)
    if cfg.rope == "learned" and positions is not None and "pos" in embed_local:
        pos_tab = embed_local["pos"]  # (maxpos/S, D)
        p_s = pos_tab.shape[0]
        plocal = positions - s_idx * p_s
        pvalid = (plocal >= 0) & (plocal < p_s)
        prows = jnp.take(pos_tab, jnp.clip(plocal, 0, p_s - 1), axis=0)
        part = part + jnp.where(pvalid[..., None], prows, 0).astype(compute_dtype)
    return lax.psum(part, eng.stage_axis)


def plain_embed(cfg, eng, embed_local, tokens, positions=None,
                compute_dtype=jnp.float32):
    x = jnp.take(embed_local["tok"], tokens, axis=0).astype(compute_dtype)
    if cfg.rope == "learned" and positions is not None and "pos" in embed_local:
        tab = embed_local["pos"]
        x = x + jnp.take(tab, jnp.minimum(positions, tab.shape[0] - 1),
                         axis=0).astype(compute_dtype)
    return x


def vp_loss(cfg: ArchConfig, eng: EngineConfig, norm_p, head_local, y,
            labels):
    """Vocab-parallel cross-entropy (mean over tokens). y (b,s,D) replicated
    across stages; head_local (D, V_pad/S)."""
    s_idx, n_stages = _stage_info(eng)
    x = lm.final_norm_apply(cfg, norm_p, y)
    logits = jnp.einsum("bsd,dv->bsv", x, head_local).astype(jnp.float32)
    v_s = logits.shape[-1]
    gid = s_idx * v_s + jnp.arange(v_s)
    logits = jnp.where(gid < cfg.vocab_size, logits, -1e30)
    # the shift is a pure stabilizer — logsumexp is shift-invariant, so
    # stop_gradient is exact (pmax has no AD rule; gather+max does)
    lmax = jnp.max(
        lax.all_gather(lax.stop_gradient(jnp.max(logits, axis=-1)),
                       eng.stage_axis, axis=0), axis=0)
    sumexp = lax.psum(jnp.sum(jnp.exp(logits - lmax[..., None]), axis=-1),
                      eng.stage_axis)
    local_label = labels - s_idx * v_s
    owned = (local_label >= 0) & (local_label < v_s)
    ll = jnp.take_along_axis(
        logits, jnp.clip(local_label, 0, v_s - 1)[..., None], axis=-1)[..., 0]
    ll = lax.psum(jnp.where(owned, ll, 0.0), eng.stage_axis)
    nll = jnp.log(sumexp) + lmax - ll
    return nll.mean()


def vp_greedy_tokens(cfg: ArchConfig, eng: EngineConfig, norm_p, head_local,
                     y):
    """Vocab-parallel greedy argmax at EVERY position. y (b, s, D) ->
    ((b, s) int32 winners, (b, s) float32 max logits). The per-position math
    is identical to :func:`vp_greedy_token` — speculative verify relies on
    position i of an s-wide call matching a 1-wide call at that depth."""
    s_idx, _ = _stage_info(eng)
    x = lm.final_norm_apply(cfg, norm_p, y)
    logits = jnp.einsum("bsd,dv->bsv", x, head_local).astype(jnp.float32)
    v_s = logits.shape[-1]
    gid = s_idx * v_s + jnp.arange(v_s)
    logits = jnp.where(gid < cfg.vocab_size, logits, -1e30)
    lmax = jnp.max(logits, axis=-1)  # (b, s)
    larg = jnp.argmax(logits, axis=-1) + s_idx * v_s
    gmax = lax.pmax(lmax, eng.stage_axis)
    winner = lax.psum(jnp.where(lmax >= gmax, larg, 0), eng.stage_axis)
    count = lax.psum((lmax >= gmax).astype(jnp.int32), eng.stage_axis)
    return winner // jnp.maximum(count, 1), gmax  # (b, s), (b, s)


def vp_greedy_token(cfg: ArchConfig, eng: EngineConfig, norm_p, head_local,
                    y):
    """Vocab-parallel greedy sampling of the next token. y (b, 1, D)."""
    tok, gmax = vp_greedy_tokens(cfg, eng, norm_p, head_local, y)
    return tok[:, 0], gmax[:, 0]  # (b,), (b,)


def plain_loss(cfg, eng, norm_p, head_full, y, labels):
    x = lm.final_norm_apply(cfg, norm_p, y)
    logits = jnp.einsum("bsd,dv->bsv", x, head_full)
    return lm.cross_entropy(logits, labels)


# ---------------------------------------------------------------------------
# FSDP per-layer gather hook
# ---------------------------------------------------------------------------


def make_layer_gather(cfg: ArchConfig, eng: EngineConfig):
    """Returns fn applied to one layer's (local) params inside the stage scan:
    all-gathers the data-axis-sharded dims back to full size. Its AD transpose
    is a reduce-scatter, which IS the FSDP gradient reduction."""
    if not eng.fsdp:
        return None
    specs = param_pspecs(cfg, eng)["layers"]
    use_barrier = compat.differentiable_optimization_barrier()

    def gather(p_layer):
        def one(spec, leaf):
            # spec corresponds to (K, Lp, ...); leaf here is (...) per layer
            dims = list(spec)[2:]
            for d, ax in enumerate(dims):
                if ax == eng.data_axis:
                    out = lax.all_gather(leaf, eng.data_axis, axis=d,
                                         tiled=True)
                    # pin the gather to the param dtype: without the barrier
                    # XLA commutes downstream fp32 converts across the gather
                    # (2× ICI traffic and full-leaf fp32 temps — see the
                    # buffer-dump analysis in EXPERIMENTS.md §Perf). Old jax
                    # can't differentiate the barrier — drop the pin there
                    # (correctness over the perf hint).
                    if use_barrier:
                        out = lax.optimization_barrier(out)
                    return out
            return leaf

        return jax.tree.map(one, specs, p_layer,
                            is_leaf=lambda x: isinstance(x, P))

    return gather


# ---------------------------------------------------------------------------
# The pipelined forward (shared by train loss and serving)
# ---------------------------------------------------------------------------


def _slot_ids(eng: EngineConfig, slot):
    k = jnp.clip(slot % eng.n_trials, 0, eng.n_trials - 1)
    m = jnp.clip(slot // eng.n_trials, 0, eng.n_microbatches - 1)
    return k, m


def _take2(tree, i, j):
    """tree leaves (K, M, ...) -> (...) at [i, j] (dynamic)."""
    return jax.tree.map(
        lambda l: lax.dynamic_index_in_dim(
            lax.dynamic_index_in_dim(l, i, 0, keepdims=False),
            j, 0, keepdims=False), tree)


def _take1(tree, i):
    return jax.tree.map(
        lambda l: lax.dynamic_index_in_dim(l, i, 0, keepdims=False), tree)


def pipeline_train_loss(cfg: ArchConfig, opts: ModelOptions, eng: EngineConfig,
                        params, batch):
    """Runs the multi-trial pipelined forward; returns per-trial (loss, aux).

    Executes *inside* shard_map. ``params`` leaves are local shards:
    layers (K, L_s, ...), embed/tok (K, V_s, D), head (K, D, V_s), etc.
    batch: tokens/labels (K, M, mb, seq) + optional extras.
    """
    S = eng.n_stages
    K, M = eng.n_trials, eng.n_microbatches
    plan = plan_stages(cfg, S)
    l_s = plan.layers_per_stage
    s_idx = lax.axis_index(eng.stage_axis)
    layer_offset = s_idx * l_s
    layer_mask = (layer_offset + jnp.arange(l_s)) < cfg.n_layers
    gather_fn = make_layer_gather(cfg, eng)

    tokens, labels = batch["tokens"], batch["labels"]
    mb, seq = tokens.shape[-2], tokens.shape[-1]
    d = cfg.d_model
    cdt = opts.compute_dtype
    pos_train = jnp.broadcast_to(jnp.arange(seq), (mb, seq))

    def embed_slot(slot):
        k, m = _slot_ids(eng, slot)
        tok = _take2({"t": tokens}, k, m)["t"]
        emb_k = _take1(params["embed"], k)
        if eng.vocab_parallel:
            x = vp_embed(cfg, eng, emb_k, tok, pos_train, cdt)
        else:
            x = plain_embed(cfg, eng, emb_k, tok, pos_train, cdt)
        if "frontend_embeds" in batch:
            fe = _take2({"f": batch["frontend_embeds"]}, k, m)["f"]
            nf = fe.shape[1]
            x = x.at[:, :nf].set(fe.astype(x.dtype))
        return x

    def slot_pos(slot):
        if cfg.rope == "mrope":
            k, m = _slot_ids(eng, slot)
            return _take2({"p": batch["mrope_pos"]}, k, m)["p"]  # (3, mb, seq)
        return pos_train

    def tick_compute(x_cur, t):
        """One tick's compute (embed + stage + head-loss). Rematerialized:
        only the carried activation is stashed per tick, which bounds the
        pipeline's activation memory at n_ticks × (mb, seq, d) — the
        difference between fitting 16 GB HBM and not (see EXPERIMENTS §Perf).
        The ppermute stays OUTSIDE so backward replays compute, not comms
        beyond what AD itself requires.

        skip_bubbles: fill/drain ticks take the cheap cond branch instead of
        computing-then-masking. Safe in SPMD because each cond predicate is
        uniform across every mesh axis its branch communicates over: the
        stage-compute branch only gathers over 'data' (validity depends on
        (t, stage) only); the embed/head branches psum over 'model' (validity
        depends on t only)."""
        # --- inject (stage 0's input for slot t) --------------------------
        valid_in = t < eng.n_slots
        if eng.skip_bubbles:
            x_emb = lax.cond(valid_in, embed_slot,
                             lambda _: jnp.zeros((mb, seq, d), cdt), t)
        else:
            x_emb = embed_slot(t)
        x_in = jnp.where(s_idx == 0, x_emb, x_cur)
        # --- stage compute -------------------------------------------------
        slot_cur = t - s_idx
        valid_cur = (slot_cur >= 0) & (slot_cur < eng.n_slots)
        k_cur, _ = _slot_ids(eng, slot_cur)
        x_in = jnp.where(valid_cur, x_in, 0.0).astype(cdt)

        def run_stage(x_in):
            p_layers = _take1(params["layers"], k_cur)
            shared = (_take1(params["shared"], k_cur)
                      if "shared" in params else None)
            y, _, aux = lm.stack_apply(
                cfg, opts, p_layers, x_in, pos=slot_pos(slot_cur),
                mode="train", shared_params=shared, layer_mask=layer_mask,
                layer_offset=layer_offset, window=0,
                layer_param_fn=gather_fn, inner_remat=eng.layer_remat)
            return y, aux

        if eng.skip_bubbles:
            y, aux = lax.cond(valid_cur, run_stage,
                              lambda x: (x, jnp.zeros((), jnp.float32)),
                              x_in)
        else:
            y, aux = run_stage(x_in)
        aux_val = jnp.where(valid_cur, aux, 0.0)
        # --- head / loss (slot finishing at the last stage) ---------------
        slot_out = t - (S - 1)
        valid_out = (slot_out >= 0) & (slot_out < eng.n_slots)
        k_out, m_out = _slot_ids(eng, slot_out)

        def run_head(y):
            y_last = lax.psum(
                jnp.where(s_idx == S - 1, y, 0.0), eng.stage_axis)
            lbl = _take2({"l": labels}, k_out, m_out)["l"]
            norm_k = _take1({"n": params["final_norm"]}, k_out)["n"]
            head_k = _take1({"h": params["head"]}, k_out)["h"]
            if eng.vocab_parallel:
                return vp_loss(cfg, eng, norm_k, head_k, y_last, lbl)
            return plain_loss(cfg, eng, norm_k, head_k, y_last, lbl)

        if eng.skip_bubbles:
            slot_loss = lax.cond(valid_out, run_head,
                                 lambda _: jnp.zeros((), jnp.float32), y)
        else:
            slot_loss = run_head(y)
        loss_val = jnp.where(valid_out, slot_loss, 0.0)
        return y, loss_val, aux_val

    remat_tick = jax.checkpoint(tick_compute) if opts.remat else tick_compute

    def tick(carry, t):
        x_cur, loss_acc, aux_acc = carry
        y, loss_val, aux_val = remat_tick(x_cur, t)
        slot_cur = t - s_idx
        k_cur, _ = _slot_ids(eng, slot_cur)
        k_out, _ = _slot_ids(eng, t - (S - 1))
        aux_acc = aux_acc.at[k_cur].add(aux_val)
        loss_acc = loss_acc.at[k_out].add(loss_val)
        # --- advance the ring ---------------------------------------------
        if S > 1:
            perm = [(i, (i + 1) % S) for i in range(S)]
            x_next = lax.ppermute(y, eng.stage_axis, perm)
        else:
            x_next = y
        return (x_next, loss_acc, aux_acc), None

    x0 = jnp.zeros((mb, seq, d), cdt)
    (xf, loss_acc, aux_acc), _ = lax.scan(
        tick, (x0, jnp.zeros((K,), jnp.float32), jnp.zeros((K,), jnp.float32)),
        jnp.arange(eng.n_ticks))
    # aux was accumulated per stage; total = sum over stages
    aux_acc = lax.psum(aux_acc, eng.stage_axis)
    return loss_acc / M, aux_acc / M


# ---------------------------------------------------------------------------
# Train step (grad + reductions + per-trial optimizer update)
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, opts: ModelOptions, eng: EngineConfig,
                    mesh, optimizer, jit: bool = True) -> Callable:
    """Builds the jitted multi-trial pipelined train step.

    Returns fn(params, opt_state, batch, hparams, step) ->
    (params, opt_state, metrics). ``hparams`` is a dict of (K,) arrays
    (per-trial learning rates etc. — Hydra's model-selection axis).
    """
    pspecs = param_pspecs(cfg, eng)
    ospecs = optimizer.state_pspecs(pspecs)
    bspecs = batch_pspecs(cfg, eng, train=True)

    def inner(params, opt_state, batch, hparams, step):
        # objective normalization: grads are psum'd over the data(+pod) axes,
        # so divide the local objective by the DP degree — the CE term then
        # equals the global-batch mean exactly; the MoE aux term is defined
        # per data-shard microbatch (Switch-style) and averaged.
        dp_degree = eng.data_size * eng.pod_size

        def local_loss(p):
            loss_vec, aux_vec = pipeline_train_loss(cfg, opts, eng, p, batch)
            total = loss_vec.sum()
            if cfg.moe is not None:
                total = total + cfg.moe.load_balance_coef * aux_vec.sum()
            return total / dp_degree, loss_vec

        grads, loss_vec = jax.grad(local_loss, has_aux=True)(params)
        grads, gnorm = reduce_grads(cfg, eng, grads)
        params_new, opt_new = optimizer.update(params, grads, opt_state,
                                               hparams, step, grad_norm=gnorm)
        # per-trial loss averaged over the data(+pod) axes
        for ax in eng.dp_axes:
            loss_vec = lax.pmean(loss_vec, ax)
        metrics = {"loss": loss_vec, "grad_norm": gnorm}
        return params_new, opt_new, metrics

    mapped = shard_map(
        inner, mesh=mesh,
        in_specs=(pspecs, ospecs, bspecs, P(), P()),
        out_specs=(pspecs, ospecs, {"loss": P(), "grad_norm": P()}),
        check_vma=False)
    if not jit:
        return mapped
    return jax.jit(mapped, donate_argnums=(0, 1))


def reduce_grads(cfg: ArchConfig, eng: EngineConfig, grads):
    """Explicit gradient reductions + per-trial global grad norm.

    Leaves sharded over an axis already carry a *summed* gradient for that
    axis (the all_gather/psum transposes inside AD produce it); replicated
    leaves need an explicit psum. The per-trial norm weights each leaf's
    square-sum once regardless of replication.
    """
    pspecs = param_pspecs(cfg, eng)
    k = eng.n_trials
    # sq-sum accumulators keyed by which axes still shard the (reduced) grad
    acc = {"both": jnp.zeros((k,), jnp.float32),
           "stage": jnp.zeros((k,), jnp.float32),
           "data": jnp.zeros((k,), jnp.float32),
           "none": jnp.zeros((k,), jnp.float32)}

    def one(g, spec):
        axes_in_spec = [a for a in jax.tree.leaves(tuple(spec))
                        if isinstance(a, str)]
        out = g
        if eng.data_axis not in axes_in_spec:
            out = lax.psum(out, eng.data_axis)
        if eng.stage_axis not in axes_in_spec:
            out = lax.psum(out, eng.stage_axis)
        if eng.pod_axis is not None:
            out = lax.psum(out, eng.pod_axis)
        sq = jnp.sum(jnp.square(out.astype(jnp.float32)),
                     axis=tuple(range(1, out.ndim)))
        s_sh = eng.stage_axis in axes_in_spec
        d_sh = eng.data_axis in axes_in_spec
        key = ("both" if s_sh and d_sh else "stage" if s_sh
               else "data" if d_sh else "none")
        acc[key] = acc[key] + sq
        return out

    flat_g, treedef = jax.tree.flatten(grads)
    flat_s = treedef.flatten_up_to(pspecs)
    out = [one(g, s) for g, s in zip(flat_g, flat_s)]
    total = (lax.psum(acc["both"], (eng.stage_axis, eng.data_axis))
             + lax.psum(acc["stage"], eng.stage_axis)
             + lax.psum(acc["data"], eng.data_axis)
             + acc["none"])
    gnorm = jnp.sqrt(total)
    return jax.tree.unflatten(treedef, out), gnorm


# ---------------------------------------------------------------------------
# Serving: pipelined prefill / decode (forward-only, KV/SSM cache threading)
# ---------------------------------------------------------------------------


def shared_slots_per_stage(cfg: ArchConfig, plan: StagePlan) -> int:
    """Uniform (max) shared-attention site count per stage (SPMD padding)."""
    if cfg.hybrid is None:
        return 0
    return max(lm.n_shared_sites(cfg, plan.layer_offset(s),
                                 plan.layers_per_stage)
               for s in range(plan.n_stages))


def _check_paged_support(cfg: ArchConfig, eng: EngineConfig) -> None:
    if cfg.family in ("ssm", "hybrid") or cfg.hybrid is not None:
        raise ValueError(
            "paged KV-cache supports attention-family archs only (SSM/conv "
            "states are O(1) per row and have nothing to page)")
    if eng.n_blocks < 1:
        raise ValueError("paged serving needs n_blocks >= 1 "
                         "(see scheduler.plan_serve_capacity)")
    dp = 1 if eng.batch_replicated else eng.data_size * eng.pod_size
    if eng.n_blocks % dp:
        raise ValueError(f"n_blocks={eng.n_blocks} must divide evenly over "
                         f"the {dp} data-parallel pool partitions")


def serve_cache_struct(cfg: ArchConfig, eng: EngineConfig,
                       dry_run: bool = True):
    """Global cache pytree (ShapeDtypeStructs) for the serving pipeline.

    Dense layout: layer leaves (K, M, Lp, mb_global, ...) with Lp sharded
    over the stage axis; shared-site leaves (K, M, S*slots, mb_global, ...).
    Paged layout (``eng.paged``): one block *pool* per (trial, layer) shared
    by every slot cell — leaves (K, Lp, n_blocks, block_size, h_kv, hd) with
    the n_blocks axis sharded over the data/pod axes (each shard's rows
    reach only its own pool slice, via local ids in the block tables).
    """
    plan = plan_stages(cfg, eng.n_stages)
    if eng.paged:
        _check_paged_support(cfg, eng)
        layers = {
            "k": jax.ShapeDtypeStruct(
                (eng.n_trials, plan.padded_layers, eng.n_blocks,
                 eng.block_size, cfg.n_kv_heads, cfg.head_dim),
                eng.cache_dtype),
            "v": jax.ShapeDtypeStruct(
                (eng.n_trials, plan.padded_layers, eng.n_blocks,
                 eng.block_size, cfg.n_kv_heads, cfg.head_dim),
                eng.cache_dtype),
        }
        tree = {"layers": layers, "shared": None}
        if dry_run:
            return tree
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), tree)
    mb_global = eng.microbatch * (1 if eng.batch_replicated
                                  else eng.data_size * eng.pod_size)
    one = BLK.layer_cache_shape(cfg, mb_global, eng.max_seq, eng.cache_dtype)
    lead = (eng.n_trials, eng.cache_groups, plan.padded_layers)
    layers = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(lead + s.shape, s.dtype), one)
    shared = None
    if cfg.hybrid is not None:
        s_one = BLK.shared_cache_shape(cfg, mb_global, eng.max_seq,
                                       eng.cache_dtype, eng.window)
        n_slots = eng.n_stages * shared_slots_per_stage(cfg, plan)
        shared = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                (eng.n_trials, eng.cache_groups, n_slots) + s.shape,
                s.dtype), s_one)
    tree = {"layers": layers, "shared": shared}
    if dry_run:
        return tree
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), tree)


def serve_cache_pspecs(cfg: ArchConfig, eng: EngineConfig):
    st = eng.stage_axis
    batch_ax = None if eng.batch_replicated else eng.dp_axes
    if eng.paged:
        # pool: layers over stages, blocks over the data/pod axes
        spec = P(None, st, batch_ax, None, None, None)
        return {"layers": {"k": spec, "v": spec}, "shared": None}
    plan = plan_stages(cfg, eng.n_stages)
    one = BLK.layer_cache_shape(cfg, 1, max(eng.max_seq, 1), eng.cache_dtype)
    layers = jax.tree.map(
        lambda s: P(None, None, st, batch_ax, *([None] * (len(s.shape) - 1))),
        one)
    shared = None
    if cfg.hybrid is not None:
        s_one = BLK.shared_cache_shape(cfg, 1, max(eng.max_seq, 1),
                                       eng.cache_dtype, eng.window)
        shared = jax.tree.map(
            lambda s: P(None, None, st, batch_ax,
                        *([None] * (len(s.shape) - 1))), s_one)
    return {"layers": layers, "shared": shared}


def pipeline_serve(cfg: ArchConfig, opts: ModelOptions, eng: EngineConfig,
                   params, cache, batch, mode: str):
    """Pipelined forward for serving; runs inside shard_map.

    decode: batch = {tokens (K,M,mb,1), positions (K,M,mb)}; one new token per
    sequence against the live cache.
    prefill: batch = {tokens (K,M,mb,seq)} (+ frontend extras); fills the
    cache and emits the first generated token.
    append: batch = {tokens (K,M,mb,qlen), positions (K,M,mb)}; inserts qlen
    tokens per row starting at the row's own cache depth ``positions`` —
    the continuous-batching admission path (chunked prefill of new requests
    into recycled slots, per-row ragged offsets). The K axis is the
    co-serving axis: every slot tick indexes params, cache slices, and block
    tables by its own trial k, so one call advances cells of K different
    model variants at once.
    mixed: append plus ``batch["qlens"]`` (K,M,mb) int32 per-row real query
    counts — one fused tick advancing prefill chunks (qlen = chunk width)
    AND decode rows (qlen = 1) AND idle rows (qlen = 0) in a single ragged
    wave padded to the wave max. Padded positions are never written to the
    cache and attend to nothing; the head samples each row at its own last
    real position (qlens - 1) instead of the trailing column.
    verify: mixed's ragged-append semantics with a per-POSITION head readout
    — ``tokens_out``/``logit_max`` come back (K,M,mb,qlen), holding each
    row's greedy argmax at every query position instead of only the last.
    Position i's token is what decode at that depth would emit (same key
    set, masked scores contribute exactly 0), which is the speculative-
    decoding contract: the target verifies a drafter's gamma proposals plus
    its own bonus token in one call. Outputs at positions >= a row's qlens
    are garbage (clamped padding) — callers slice by qlens.
    All modes accept an optional ``batch["active"]`` (K,M,mb) bool row mask:
    inactive rows compute (SPMD shapes are static) but their cache rows are
    left untouched, so idle slots can ride along in a live batch.
    ``eng.paged`` (append/decode only): the cache holds per-layer block pools
    and batch additionally carries ``block_tables`` (K,M,mb,max_blocks) int32
    local physical ids; K/V writes scatter through the tables and reads
    gather each row's logical view (blocks.paged_kv_update), so the live HBM
    cache footprint is the pool, not slots × max_seq.
    Returns (new_cache, tokens_out (K,M,mb), logit_max (K,M,mb)).
    """
    if eng.paged and mode not in ("append", "decode", "mixed", "verify"):
        raise ValueError(f"paged serving supports append/decode/mixed/verify "
                         f"only, got mode={mode!r}")
    S = eng.n_stages
    K, M = eng.n_trials, eng.n_microbatches
    plan = plan_stages(cfg, S)
    l_s = plan.layers_per_stage
    s_idx = lax.axis_index(eng.stage_axis)
    layer_offset = s_idx * l_s
    layer_mask = (layer_offset + jnp.arange(l_s)) < cfg.n_layers
    gather_fn = make_layer_gather(cfg, eng)
    n_sh = shared_slots_per_stage(cfg, plan)

    tokens = batch["tokens"]
    mb, qlen = tokens.shape[-2], tokens.shape[-1]
    cdt = opts.compute_dtype
    nc = eng.prefill_chunks if (mode == "prefill"
                                and eng.prefill_chunks > 1) else 1
    ragged = mode in ("append", "mixed", "verify")
    stack_mode = "append" if (nc > 1 or ragged) else mode
    active = batch.get("active")
    qlens = batch.get("qlens") if mode in ("mixed", "verify") else None

    def chunk_of(m):
        return m % nc if nc > 1 else jnp.zeros((), jnp.int32)

    def slot_rows_active(k, m):
        if active is None:
            return None
        return _take2({"a": active}, k, m)["a"]  # (mb,) bool

    def embed_slot(slot):
        k, m = _slot_ids(eng, slot)
        tok = _take2({"t": tokens}, k, m)["t"]
        if mode == "decode":
            pos = _take2({"p": batch["positions"]}, k, m)["p"][:, None]
        elif ragged:
            pos = slot_pos(slot)  # (mb, qlen) per-row absolute positions
        else:
            pos = chunk_of(m) * qlen + jnp.broadcast_to(
                jnp.arange(qlen), (mb, qlen))
        emb_k = _take1(params["embed"], k)
        if eng.vocab_parallel:
            x = vp_embed(cfg, eng, emb_k, tok, pos, cdt)
        else:
            x = plain_embed(cfg, eng, emb_k, tok, pos, cdt)
        if mode == "prefill" and "frontend_embeds" in batch:
            fe = _take2({"f": batch["frontend_embeds"]}, k, m)["f"]
            x = x.at[:, :fe.shape[1]].set(fe.astype(x.dtype))
        return x

    def slot_pos(slot):
        k, m = _slot_ids(eng, slot)
        if mode == "decode":
            p = _take2({"p": batch["positions"]}, k, m)["p"][:, None]  # (mb,1)
            if cfg.rope == "mrope":
                return jnp.broadcast_to(p, (3, mb, 1))
            return p
        if ragged:
            start = _take2({"p": batch["positions"]}, k, m)["p"]
            pos = start[:, None] + jnp.arange(qlen)[None, :]
            if qlens is not None:
                # clamp padded positions to the row's last real one — they
                # are compute-only (writes dropped, outputs discarded) but
                # must stay inside any position-table/rope range
                ql = _take2({"q": qlens}, k, m)["q"]
                pos = jnp.minimum(
                    pos, (start + jnp.maximum(ql - 1, 0))[:, None])
            return pos
        if cfg.rope == "mrope":
            return _take2({"p": batch["mrope_pos"]}, k, m)["p"]
        return chunk_of(m) * qlen + jnp.broadcast_to(
            jnp.arange(qlen), (mb, qlen))

    def slot_cache(cache, k, m):
        """Local (L_s, ...) cache slice of one slot (+ local shared sites).
        Chunked prefill: the nc chunk-slots of a request group share one
        cache (group = m // nc); chunk order through the pipeline guarantees
        chunk c's write lands at each stage before chunk c+1 reads it."""
        g = m // nc if nc > 1 else m
        lay = _take2(cache["layers"], k, g)
        sh = None
        if cache["shared"] is not None:
            sh = _take2(cache["shared"], k, g)
        return {"layers": lay, "shared": sh}

    def put_cache(cache, k, m, new_slice, valid, row_mask=None):
        m = m // nc if nc > 1 else m

        def upd(buf, new):
            old = lax.dynamic_index_in_dim(
                lax.dynamic_index_in_dim(buf, k, 0, keepdims=False),
                m, 0, keepdims=False)
            keep = valid
            if row_mask is not None:
                # cache slices are (L_s|sites, mb, ...): rows live on axis 1
                keep = (valid & row_mask).reshape(
                    (1, row_mask.shape[0]) + (1,) * (new.ndim - 2))
            val = jnp.where(keep, new.astype(buf.dtype), old)
            return lax.dynamic_update_slice(
                buf, val[None, None],
                (k, m) + (0,) * (buf.ndim - 2))
        out = {"layers": jax.tree.map(upd, cache["layers"],
                                      new_slice["layers"])}
        if cache["shared"] is not None:
            out["shared"] = jax.tree.map(upd, cache["shared"],
                                         new_slice["shared"])
        else:
            out["shared"] = None
        return out

    def tick(carry, t):
        x_cur, cache, tok_out, val_out = carry
        valid_in = t < eng.n_slots
        if eng.skip_bubbles:
            x_emb = lax.cond(
                valid_in, embed_slot,
                lambda _: jnp.zeros((mb, qlen, cfg.d_model), cdt), t)
        else:
            x_emb = embed_slot(t)
        x_in = jnp.where(s_idx == 0, x_emb, x_cur)
        slot_cur = t - s_idx
        valid_cur = (slot_cur >= 0) & (slot_cur < eng.n_slots)
        k_cur, m_cur = _slot_ids(eng, slot_cur)
        x_in = jnp.where(valid_cur, x_in, 0.0).astype(cdt)

        def run_stage(operand):
            x_in, cache = operand
            p_layers = _take1(params["layers"], k_cur)
            shared = (_take1(params["shared"], k_cur)
                      if "shared" in params else None)
            kv_off = None
            if mode == "decode" or ragged:
                kv_off = _take2({"p": batch["positions"]}, k_cur, m_cur)["p"]
            elif nc > 1:
                kv_off = jnp.full((mb,), chunk_of(m_cur) * qlen, jnp.int32)
            ql_cur = None
            if qlens is not None:
                ql_cur = _take2({"q": qlens}, k_cur, m_cur)["q"]
            if eng.paged:
                # the pool is shared across slots: slice per trial only, and
                # gate writes (idle rows, bubble ticks) inside the scatter —
                # a where-style masked write-back would race rows that share
                # the pool leaf
                rows = slot_rows_active(k_cur, m_cur)
                wm = jnp.broadcast_to(valid_cur, (mb,))
                if rows is not None:
                    wm = wm & rows
                c_slice = {"layers": _take1(cache["layers"], k_cur),
                           "shared": None}
                bt = _take2({"b": batch["block_tables"]}, k_cur, m_cur)["b"]
                y, c_new, _ = lm.stack_apply(
                    cfg, opts, p_layers, x_in, pos=slot_pos(slot_cur),
                    mode=stack_mode, cache=c_slice, shared_params=shared,
                    layer_mask=layer_mask, layer_offset=layer_offset,
                    kv_offset=kv_off, window=eng.window,
                    layer_param_fn=gather_fn, block_tables=bt, write_mask=wm,
                    q_lens=ql_cur)
                new_layers = jax.tree.map(
                    lambda buf, new: lax.dynamic_update_slice(
                        buf, new[None].astype(buf.dtype),
                        (k_cur,) + (0,) * (buf.ndim - 1)),
                    cache["layers"], c_new["layers"])
                return y, {"layers": new_layers, "shared": None}
            c_slice = slot_cache(cache, k_cur, m_cur)
            y, c_new, _ = lm.stack_apply(
                cfg, opts, p_layers, x_in, pos=slot_pos(slot_cur),
                mode=stack_mode, cache=c_slice, shared_params=shared,
                layer_mask=layer_mask, layer_offset=layer_offset,
                kv_offset=kv_off, window=eng.window,
                layer_param_fn=gather_fn, q_lens=ql_cur)
            return y, put_cache(cache, k_cur, m_cur, c_new, valid_cur,
                                slot_rows_active(k_cur, m_cur))

        if eng.skip_bubbles:
            y, cache = lax.cond(valid_cur, run_stage,
                                lambda op: (op[0], op[1]), (x_in, cache))
        else:
            y, cache = run_stage((x_in, cache))
        # head: greedy next token for the slot draining at the last stage
        slot_out = t - (S - 1)
        valid_out = (slot_out >= 0) & (slot_out < eng.n_slots)
        k_out, m_out = _slot_ids(eng, slot_out)
        norm_k = _take1({"n": params["final_norm"]}, k_out)["n"]
        head_k = _take1({"h": params["head"]}, k_out)["h"]
        if mode == "verify":
            # speculative verify: greedy argmax at EVERY query position —
            # the drafter's proposals and the target's bonus token are all
            # judged from one call (outputs past a row's qlens are clamped
            # padding; the engine slices by qlens)
            y_all = lax.psum(jnp.where(s_idx == S - 1, y, 0.0),
                             eng.stage_axis)
            if eng.vocab_parallel:
                nxt, lmax = vp_greedy_tokens(cfg, eng, norm_k, head_k, y_all)
            else:
                x_h = lm.final_norm_apply(cfg, norm_k, y_all)
                logits = jnp.einsum("bsd,dv->bsv", x_h, head_k)
                nxt, lmax = jnp.argmax(logits, -1), jnp.max(logits, -1)
            idx4 = (k_out, m_out, 0, 0)
        else:
            if qlens is not None:
                # mixed ragged wave: each row's chunk ends at its own
                # qlens - 1, not the padded trailing column
                ql_out = _take2({"q": qlens}, k_out, m_out)["q"]
                sel = jnp.clip(ql_out - 1, 0, qlen - 1)[:, None, None]
                y_head = jnp.take_along_axis(y, sel, axis=1)
            else:
                y_head = y[:, -1:]
            y_last = lax.psum(jnp.where(s_idx == S - 1, y_head, 0.0),
                              eng.stage_axis)
            if eng.vocab_parallel:
                nxt, lmax = vp_greedy_token(cfg, eng, norm_k, head_k, y_last)
            else:
                x_h = lm.final_norm_apply(cfg, norm_k, y_last)
                logits = jnp.einsum("bsd,dv->bsv", x_h, head_k)[:, 0]
                nxt, lmax = jnp.argmax(logits, -1), jnp.max(logits, -1)
            idx4 = (k_out, m_out, 0)
        upd_tok = jnp.where(valid_out, nxt.astype(jnp.int32),
                            lax.dynamic_index_in_dim(
                                lax.dynamic_index_in_dim(
                                    tok_out, k_out, 0, False), m_out, 0,
                                False))
        tok_out = lax.dynamic_update_slice(
            tok_out, upd_tok[None, None], idx4)
        upd_val = jnp.where(valid_out, lmax.astype(jnp.float32),
                            lax.dynamic_index_in_dim(
                                lax.dynamic_index_in_dim(
                                    val_out, k_out, 0, False), m_out, 0,
                                False))
        val_out = lax.dynamic_update_slice(
            val_out, upd_val[None, None], idx4)
        if S > 1:
            perm = [(i, (i + 1) % S) for i in range(S)]
            x_next = lax.ppermute(y, eng.stage_axis, perm)
        else:
            x_next = y
        return (x_next, cache, tok_out, val_out), None

    x0 = jnp.zeros((mb, qlen, cfg.d_model), cdt)
    out_shape = (K, M, mb, qlen) if mode == "verify" else (K, M, mb)
    tok0 = jnp.zeros(out_shape, jnp.int32)
    val0 = jnp.zeros(out_shape, jnp.float32)
    (xf, cache, tok_out, val_out), _ = lax.scan(
        tick, (x0, cache, tok0, val0), jnp.arange(eng.n_ticks))
    return cache, tok_out, val_out


def make_serve_step(cfg: ArchConfig, opts: ModelOptions, eng: EngineConfig,
                    mesh, mode: str, jit: bool = True,
                    with_active: bool = False, tracer=None) -> Callable:
    """Builds the jitted pipelined serving step.

    ``mode``: prefill | decode | append | mixed | verify. ``append`` is the
    continuous-batching admission step: qlen tokens per row inserted at
    per-row cache depths (batch carries ``positions`` start offsets).
    ``mixed`` is the fused-admission tick: append semantics plus a (K,M,mb)
    int32 ``qlens`` batch entry giving each row's real query count (chunk
    width / 1 for decode / 0 for idle), so one program advances prefill and
    decode rows together. ``verify`` is the speculative-decoding target
    call: mixed's ragged append with a per-position head readout — tokens
    and logit_max come back (K,M,mb,qlen). ``with_active=True`` adds a
    (K,M,mb) bool ``active`` row mask to the batch: inactive rows never touch
    their cache (the serve engine uses it to let idle/decoding slots ride
    along during admission and vice versa).
    ``tracer`` (an *enabled* ``repro.obs.Tracer``) wraps the step to emit a
    ``compile`` event on the first call of each (token qlen, block-table
    width) shape signature — exactly the signatures XLA retraces, so the
    serving timeline shows every shape-bucket recompile. Pass None (not a
    NullTracer) when tracing is off: the returned step is then the bare
    jitted fn with zero wrapper overhead.
    Returns fn(params, cache, batch) -> (new_cache, tokens, logit_max).
    """
    if mode in ("append", "mixed", "verify") and cfg.rope == "mrope":
        raise ValueError("append mode (continuous batching) does not support "
                         "mrope archs; use the static prefill path")
    if mode in ("mixed", "verify") and cfg.family in ("ssm", "hybrid"):
        raise ValueError("mixed-tick/verify serving is attention-family "
                         "only: ragged padded tokens would advance "
                         "recurrent SSM state")
    pspecs = param_pspecs(cfg, eng)
    bspecs = batch_pspecs(cfg, eng, train=False)
    if mode == "prefill":
        bspecs.pop("positions", None)
    else:  # decode/append consume plain tokens; modality prefixes live in
        # the cache (written by a static prefill)
        bspecs.pop("frontend_embeds", None)
        bspecs.pop("mrope_pos", None)
    if mode in ("mixed", "verify"):
        bspecs["qlens"] = P(None, None,
                            None if eng.batch_replicated else eng.dp_axes)
    if with_active:
        bspecs["active"] = P(None, None,
                             None if eng.batch_replicated else eng.dp_axes)
    if eng.paged:
        # (K, M, mb_global, max_blocks) local physical ids, rows sharded
        # with the batch so each shard sees only tables into its pool slice
        bspecs["block_tables"] = P(
            None, None, None if eng.batch_replicated else eng.dp_axes, None)
    cspecs = serve_cache_pspecs(cfg, eng)
    if mode == "verify":  # per-position outputs carry a trailing qlen axis
        batch_ax = (P() if eng.batch_replicated
                    else P(None, None, eng.dp_axes, None))
    else:
        batch_ax = P() if eng.batch_replicated else P(None, None, eng.dp_axes)

    def inner(params, cache, batch):
        return pipeline_serve(cfg, opts, eng, params, cache, batch, mode)

    mapped = shard_map(
        inner, mesh=mesh,
        in_specs=(pspecs, cspecs, bspecs),
        out_specs=(cspecs, batch_ax, batch_ax),
        check_vma=False)
    fn = jax.jit(mapped, donate_argnums=(1,)) if jit else mapped
    if tracer is None or not tracer.enabled:
        return fn
    seen: set = set()

    def traced(params, cache, batch):
        bt = batch.get("block_tables")
        key = (int(batch["tokens"].shape[-1]),
               int(bt.shape[-1]) if bt is not None else 0)
        if key not in seen:
            seen.add(key)
            tracer.compile(mode, qlen=key[0], table_width=key[1])
        return fn(params, cache, batch)

    return traced


def make_slot_reset(cfg: ArchConfig, eng: EngineConfig, mesh,
                    jit: bool = True) -> Callable:
    """Builds fn(cache, mask) zeroing the cache rows of recycled slots.

    ``mask``: (K, cache_groups, mb_global) bool — True rows are cleared the
    tick their request finishes, before a queued request is admitted into the
    freed slot. KV rows beyond kv_len are never attended, but SSM/conv states
    are recurrent and MUST restart from zero for the next request.
    (Paged engines never call this: paged serving is attention-only, stale
    pool blocks are masked by kv_len, and freed blocks return to the
    allocator host-side.)
    """
    if eng.paged:
        raise ValueError("paged caches need no slot reset (no recurrent "
                         "state; stale blocks are masked via kv_len)")
    cspecs = serve_cache_pspecs(cfg, eng)
    mspec = P(None, None, None if eng.batch_replicated else eng.dp_axes)

    def inner(cache, mask):
        def zero(buf):
            mk = mask.reshape(mask.shape[:2] + (1, mask.shape[2])
                              + (1,) * (buf.ndim - 4))
            return jnp.where(mk, jnp.zeros((), buf.dtype), buf)

        return {"layers": jax.tree.map(zero, cache["layers"]),
                "shared": (jax.tree.map(zero, cache["shared"])
                           if cache["shared"] is not None else None)}

    mapped = shard_map(inner, mesh=mesh, in_specs=(cspecs, mspec),
                           out_specs=cspecs, check_vma=False)
    if not jit:
        return mapped
    return jax.jit(mapped, donate_argnums=(0,))


@dataclasses.dataclass
class TransferKernels:
    """The three block-movement primitives consumed by
    ``serve.transfer.TransferEngine`` (the sole caller — block movement has
    no one-shot public API; every copy/swap is enqueued on the transfer
    engine and batched per engine round)."""

    copy: Callable  # (cache, src, dst) -> cache; compiled pool copy
    extract: Callable  # (cache, k, shard, local_ids) -> [payload, ...]
    inject: Callable  # (cache, k, shard, local_ids, payloads) -> cache


def make_transfer_kernels(cfg: ArchConfig, eng: EngineConfig, mesh,
                          jit: bool = True) -> TransferKernels:
    """Builds the device kernels behind the serve transfer engine.

    **copy(cache, src, dst)** — batched device pool copy dst := src per
    layer, the copy-on-write half of prefix sharing: before a row may write
    into a partially-matched *shared* block (refcount > 1), the engine forks
    it — allocates a private block and copies the shared block's K/V rows
    into it, so no shared block is ever mutated. ``src``/``dst`` are
    (K, dp, n_copies) int32 *local* physical ids per (trial, data-shard)
    pool partition, -1 = no-op padding; a block id addresses the same slot
    of every layer's pool leaf, so one call moves all layers.

    **extract(cache, k, shard, local_ids)** — device → host: read trial k /
    shard's pool blocks out to one host payload per id (a (2, Lp,
    block_size, h_kv, hd) array stacking K and V). Read-only — extracting a
    shared block is always safe — and eager: spill/retract callers free the
    device block immediately after.

    **inject(cache, k, shard, local_ids, payloads)** — host → device: write
    extracted payloads back into (freshly allocated) pool blocks. Inverse
    of extract; round-trips bit-exactly.

    Extraction/injection address the *global* pool leaf (the n_blocks axis
    concatenates the dp shards), so local ids are offset by the shard's
    slice before indexing.
    """
    _check_paged_support(cfg, eng)
    cspecs = serve_cache_pspecs(cfg, eng)
    ispec = P(None, None if eng.batch_replicated else eng.dp_axes, None)

    def inner(cache, src, dst):
        s, d = src[:, 0], dst[:, 0]  # local shard: (K, n_copies)

        def upd(buf):  # (K, Lp_local, nb_local, bs, h_kv, hd)
            nb = buf.shape[2]

            def one(bufk, sk, dk):
                vals = jnp.take(bufk, jnp.clip(sk, 0, nb - 1), axis=1)
                dk = jnp.where((sk >= 0) & (dk >= 0), dk, nb)  # OOB: dropped
                return bufk.at[:, dk].set(vals, mode="drop")

            return jax.vmap(one)(buf, s, d)

        return {"layers": jax.tree.map(upd, cache["layers"]), "shared": None}

    mapped = shard_map(inner, mesh=mesh, in_specs=(cspecs, ispec, ispec),
                       out_specs=cspecs, check_vma=False)
    copy_fn = jax.jit(mapped, donate_argnums=(0,)) if jit else mapped

    dp = 1 if eng.batch_replicated else eng.data_size * eng.pod_size
    per_shard = max(eng.n_blocks // dp, 1)

    def _gids(shard, local_ids):
        return np.asarray([shard * per_shard + i for i in local_ids],
                          np.int32)

    def extract(cache, k, shard, local_ids):
        gids = _gids(shard, local_ids)
        # advanced indices (k, gids) split by the layer slice: result is
        # (n, Lp, block_size, h_kv, hd)
        kv = np.asarray(cache["layers"]["k"][k, :, gids])
        vv = np.asarray(cache["layers"]["v"][k, :, gids])
        return [np.stack([kv[j], vv[j]]) for j in range(len(local_ids))]

    def inject(cache, k, shard, local_ids, payloads):
        gids = _gids(shard, local_ids)
        pk = jnp.asarray(np.stack([p[0] for p in payloads]))
        pv = jnp.asarray(np.stack([p[1] for p in payloads]))
        lk = cache["layers"]["k"].at[k, :, gids].set(pk)
        lv = cache["layers"]["v"].at[k, :, gids].set(pv)
        return {"layers": {"k": lk, "v": lv}, "shared": None}

    return TransferKernels(copy=copy_fn, extract=extract, inject=inject)


def batch_pspecs(cfg: ArchConfig, eng: EngineConfig, train: bool):
    """PartitionSpecs for the (K, M, batch, ...) slot-major batch arrays."""
    dp = P(None, None, None if eng.batch_replicated else eng.dp_axes)
    specs = {"tokens": dp}
    if train:
        specs["labels"] = dp
    else:
        specs["positions"] = dp
    if cfg.frontend is not None:
        specs["frontend_embeds"] = dp
    if cfg.rope == "mrope":
        # (K, M, 3, mb, seq): batch dim is 3rd
        specs["mrope_pos"] = P(None, None, None,
                               None if eng.batch_replicated else eng.dp_axes)
    return specs
