"""Layer→stage partitioner: maps a model's layer stack onto pipeline stages.

The paper's "sharder" component. For homogeneous stacks (every assigned arch)
the optimal contiguous partition is the balanced one; we pad the layer count
to ``stages × layers_per_stage`` with masked no-op layers — padding is free in
steady state because the pipeline tick time equals the *maximum* stage load
either way (DESIGN.md §2). A cost-model-driven contiguous partitioner is also
provided for heterogeneous stacks and used by the scheduler's what-if analyses.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """How one architecture's layers map onto ``n_stages`` pipeline stages."""

    n_layers: int          # real layers
    n_stages: int
    layers_per_stage: int  # local (padded) layer count L_s
    padded_layers: int     # n_stages * layers_per_stage

    def layer_offset(self, stage: int) -> int:
        return stage * self.layers_per_stage

    def real_layers_in_stage(self, stage: int) -> int:
        lo = self.layer_offset(stage)
        return max(0, min(self.n_layers - lo, self.layers_per_stage))

    @property
    def pad_fraction(self) -> float:
        return 1.0 - self.n_layers / self.padded_layers

    @property
    def max_stage_layers(self) -> int:
        return max(self.real_layers_in_stage(s) for s in range(self.n_stages))


def plan_stages(cfg: ArchConfig, n_stages: int) -> StagePlan:
    if n_stages < 1:
        raise ValueError("n_stages must be >= 1")
    lps = -(-cfg.n_layers // n_stages)
    return StagePlan(n_layers=cfg.n_layers, n_stages=n_stages,
                     layers_per_stage=lps, padded_layers=lps * n_stages)


# ---------------------------------------------------------------------------
# Cost model (per-layer FLOPs / bytes) — used for balance analysis and the
# scheduler's memory/throughput planning.
# ---------------------------------------------------------------------------


def layer_flops_per_token(cfg: ArchConfig, seq_len: int) -> float:
    """Approximate forward FLOPs per token for one layer (matmul-dominated)."""
    d = cfg.d_model
    if cfg.family == "ssm":
        s = cfg.ssm
        di = s.d_inner(d)
        r = s.resolved_dt_rank(d)
        proj = 2 * d * 2 * di + 2 * di * (r + 2 * s.d_state) + 2 * r * di \
            + 2 * di * d
        scan = 6 * di * s.d_state  # state update + output contraction
        return proj + scan
    if cfg.family == "hybrid":
        s = cfg.ssm
        di = s.d_inner(d)
        nh = s.n_ssm_heads(d)
        proj = 2 * d * (2 * di + 2 * s.n_groups * s.d_state + nh) + 2 * di * d
        chunk = 2 * s.chunk_size * nh * (s.d_state + s.head_dim)  # SSD intra
        scan = 6 * di * s.d_state
        base = proj + chunk + scan
        # amortized shared attention block
        attn = _attn_flops_per_token(cfg, seq_len) / cfg.hybrid.attn_every
        mlp = 6 * d * cfg.hybrid.shared_d_ff / cfg.hybrid.attn_every
        return base + attn + mlp
    flops = _attn_flops_per_token(cfg, seq_len)
    if cfg.moe is not None:
        flops += 2 * d * cfg.moe.n_experts  # router
        flops += cfg.moe.top_k * 6 * d * cfg.moe.expert_d_ff
    elif cfg.act == "swiglu":
        flops += 6 * d * cfg.d_ff
    else:
        flops += 4 * d * cfg.d_ff
    return flops


def _attn_flops_per_token(cfg: ArchConfig, seq_len: int) -> float:
    d, hd = cfg.d_model, cfg.head_dim
    qkvo = 2 * d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
        + 2 * cfg.n_heads * hd * d
    # causal attention: ~seq/2 effective kv per query
    eff = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    scores = 2 * 2 * cfg.n_heads * hd * eff / 2
    return qkvo + scores


def layer_param_bytes(cfg: ArchConfig, dtype_bytes: int = 2) -> int:
    return cfg.layer_param_count() * dtype_bytes


def partition_costs(costs: Sequence[float], n_parts: int) -> list[int]:
    """Contiguous partition of ``costs`` into ``n_parts`` minimizing the max
    part sum (linear-partition DP). Returns the start index of each part.

    Used for heterogeneous stacks; for homogeneous stacks it reduces to the
    balanced split that ``plan_stages`` assumes.
    """
    n = len(costs)
    if n_parts >= n:
        return list(range(n)) + [n] * (n_parts - n)
    prefix = [0.0]
    for c in costs:
        prefix.append(prefix[-1] + c)

    # dp[j][i] = minimal max-part-sum splitting first i items into j parts
    INF = float("inf")
    dp = [[INF] * (n + 1) for _ in range(n_parts + 1)]
    cut = [[0] * (n + 1) for _ in range(n_parts + 1)]
    for i in range(n + 1):
        dp[1][i] = prefix[i]
    for j in range(2, n_parts + 1):
        for i in range(j, n + 1):
            for k in range(j - 1, i):
                cost = max(dp[j - 1][k], prefix[i] - prefix[k])
                if cost < dp[j][i]:
                    dp[j][i] = cost
                    cut[j][i] = k
    # recover starts
    starts = [0] * n_parts
    i = n
    for j in range(n_parts, 1, -1):
        i = cut[j][i]
        starts[j - 1] = i
    starts[0] = 0
    return starts


def balance_report(cfg: ArchConfig, plan: StagePlan, seq_len: int) -> dict:
    """Per-stage FLOPs loads + imbalance factor (max/mean)."""
    per_layer = layer_flops_per_token(cfg, seq_len)
    loads = [plan.real_layers_in_stage(s) * per_layer
             for s in range(plan.n_stages)]
    mean = sum(loads) / len(loads)
    return {
        "per_stage_flops_per_token": loads,
        "imbalance": max(loads) / mean if mean else 1.0,
        "pad_fraction": plan.pad_fraction,
    }
