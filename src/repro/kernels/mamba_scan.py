"""Chunked Mamba1 selective scan for TPU (Pallas).

The recurrence h_t = da_t ⊙ h_{t-1} + dbx_t with per-(channel, state) decay is
sequential in time but parallel over (batch, d_inner, d_state). TPU-native
tiling (DESIGN.md §2): grid (batch, d_inner blocks, time chunks) with the time
chunk as the innermost *sequential* axis; the (bdi, n) state lives in fp32
VMEM scratch across chunk steps, each chunk streams (ck, bdi, n) decay/input
tiles HBM→VMEM once and emits the contracted output y = Σ_n h·C directly —
the (b, s, di, n) hidden history is never materialized in HBM (the pure-jnp
path's dominant memory cost).

Layouts: da/dbx (b, s, di, n), cmat (b, s, n), y (b, s, di), h0/h_out
(b, di, n).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    VMEM = None


def _scan_kernel(h0_ref, da_ref, dbx_ref, c_ref, y_ref, hout_ref, h_ref, *,
                 chunk: int, n_chunks: int, s_real: int):
    t_idx = pl.program_id(2)

    @pl.when(t_idx == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    da = da_ref[0].astype(jnp.float32)    # (ck, bdi, n)
    dbx = dbx_ref[0].astype(jnp.float32)  # (ck, bdi, n)
    c = c_ref[0].astype(jnp.float32)      # (ck, n)

    def step(i, carry):
        h = carry
        t_global = t_idx * chunk + i
        valid = t_global < s_real
        da_t = jnp.where(valid, da[i], 1.0)   # padded steps: identity decay
        dbx_t = jnp.where(valid, dbx[i], 0.0)
        h = da_t * h + dbx_t
        y_t = jnp.sum(h * c[i][None, :], axis=-1)  # (bdi,)
        y_ref[0, i] = y_t.astype(y_ref.dtype)
        return h

    h = lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(t_idx == n_chunks - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


def mamba_scan_bdn(da, dbx, cmat, h0, *, chunk: int = 128,
                   block_di: int = 512, interpret: bool = False):
    """da/dbx (b, s, di, n); cmat (b, s, n); h0 (b, di, n) →
    (y (b, s, di), h_final (b, di, n))."""
    b, s, di, n = da.shape
    block_di = min(block_di, di)
    assert di % block_di == 0, (di, block_di)
    chunk = min(chunk, s)
    s_p = -(-s // chunk) * chunk
    if s_p != s:
        pad = ((0, 0), (0, s_p - s), (0, 0), (0, 0))
        da = jnp.pad(da, pad)
        dbx = jnp.pad(dbx, pad)
        cmat = jnp.pad(cmat, ((0, 0), (0, s_p - s), (0, 0)))
    n_chunks = s_p // chunk
    n_di = di // block_di
    grid = (b, n_di, n_chunks)

    kernel = functools.partial(_scan_kernel, chunk=chunk, n_chunks=n_chunks,
                               s_real=s)
    # blocks move time-major so the sequential grid axis streams chunks
    y, h_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_di, n), lambda bi, d, t: (bi, d, 0)),
            pl.BlockSpec((1, chunk, block_di, n),
                         lambda bi, d, t: (bi, t, d, 0)),
            pl.BlockSpec((1, chunk, block_di, n),
                         lambda bi, d, t: (bi, t, d, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, d, t: (bi, t, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_di), lambda bi, d, t: (bi, t, d)),
            pl.BlockSpec((1, block_di, n), lambda bi, d, t: (bi, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s_p, di), da.dtype),
            jax.ShapeDtypeStruct((b, di, n), jnp.float32),
        ],
        scratch_shapes=[VMEM((block_di, n), jnp.float32)],
        interpret=interpret,
    )(h0, da, dbx, cmat)
    return y[:, :s], h_out
