"""Paged flash attention for TPU (Pallas): decode/append straight from the
block pool — no gathered logical K/V view.

The serve stack's paged path (``blocks.paged_kv_update``) scatters new K/V
into a shared ``(n_blocks, block_size, h_kv, hd)`` pool and then *gathers*
each row's full ``max_blocks * block_size`` logical view before running
dense attention — O(max_seq) HBM traffic per decode step regardless of the
row's actual ``kv_len``. This kernel removes the gather: attention reads K/V
directly from the pool through each row's block table, touching only the
blocks that hold live tokens.

Layout & grid
    q is packed ``(b, h_kv, g·sq, hd)`` (the ``g`` query heads sharing one kv
    head ride as extra rows — GQA without a materialized repeat_kv), carrying
    fp32 (m, l, acc) online-softmax state across physical blocks exactly like
    ``flash_attention.py``. Two bodies share the per-block accumulate step:

    * ``variant="blockspec"`` — grid ``(b, h_kv, n_tbl)`` with the table axis
      innermost *sequential* and (m, l, acc) in VMEM scratch; the K/V
      BlockSpec index maps stream one physical ``(block_size, hd)`` block
      into VMEM per step. This is the TPU compile target: the pool
      indirection is resolved by the pipeline before each body runs, so it
      costs index arithmetic, not a gathered copy.
    * ``variant="loop"`` — grid ``(b, h_kv)`` with the whole pool left in
      ``ANY`` memory and an in-kernel ``fori_loop`` from the first windowed
      block to ``ceil(kv_len / block_size)``, loading each live physical
      block by table entry. This is the interpret-mode/CPU execution path
      (far fewer grid steps; per-row cost scales with live length and is
      flat in table width). On TPU the same structure needs the loads
      replaced by double-buffered ``make_async_copy`` — the noted next step.

Scalar-prefetch scheme
    ``block_tables (b, n_tbl)``, ``kv_offset (b,)``, ``kv_len (b,)`` and
    ``q_lens (b,)`` are scalar-prefetched
    (``pltpu.PrefetchScalarGridSpec``): the blockspec variant's K/V index
    maps read ``block_tables[ib, t]`` to pick the physical block for grid
    step (ib, ·, t), the loop variant reads the same tables inside the
    body. Unallocated entries (-1) are clamped to block 0 and neutralized
    by the masks below.

Masking semantics (all in-kernel, per row ib)
    * ``kpos >= kv_len[ib]`` — stale pool tokens / unallocated tail: masked.
    * causal: ``kpos <= kv_offset[ib] + q_row`` (per-row ragged offsets —
      rows of one call may sit at different cache depths).
    * ``q_row >= q_lens[ib]`` — mixed-tick ragged padding: a wave packs
      rows of different chunk widths to one ``sq``; a row's padded query
      positions attend to nothing and emit zeros (decode rows are the
      ``q_lens = 1`` case, idle rows ``q_lens = 0``).
    * sliding window > 0: ``kpos > qpos - window``.
    * table steps with no live position (``t·block_size >= kv_len[ib]``, or
      wholly below the window) are skipped — ``pl.when`` in the blockspec
      variant, the loop bounds in the loop variant — so decode cost scales
      with the row's live length, not the table width.

``ops.paged_attention`` handles layout packing, row padding and
interpret-mode dispatch; ``ref.paged_attention_ref`` is the gather-then-
attend oracle both variants are swept against in
tests/test_kernels_paged.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:  # TPU-specific grid/memory spaces; interpretable on CPU too
    from jax.experimental.pallas import tpu as pltpu
    VMEM = pltpu.VMEM
    PrefetchScalarGridSpec = pltpu.PrefetchScalarGridSpec
except Exception:  # pragma: no cover - very old jax
    pltpu = None
    VMEM = None
    PrefetchScalarGridSpec = None

NEG_INF = -1e30


def _accumulate(q, k, v, t, off, kv_end, q_len, m_prev, l_prev, acc_prev, *,
                scale, causal, window, block_size, sq_real, rows_real):
    """One online-softmax step over physical block ``t`` (all fp32).

    q (rows, hd), k/v (block_size, hd); returns updated (m, l, acc).
    ``q_len`` masks ragged query padding (mixed-tick waves); fully masked
    rows keep m at NEG_INF so they finalize to zeros. Shared by both kernel
    variants so the masking semantics cannot drift.
    """
    s = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                        preferred_element_type=jnp.float32) * scale
    rows = s.shape[0]
    ri = lax.broadcasted_iota(jnp.int32, (rows, block_size), 0)
    qi = ri % sq_real  # row = head_in_group * sq_real + query_index
    kpos = t * block_size + lax.broadcasted_iota(
        jnp.int32, (rows, block_size), 1)
    qpos = off + qi
    mask = (kpos < kv_end) & (ri < rows_real) & (qi < q_len)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(m_new <= NEG_INF, 0.0, m_new)
    p = jnp.where(mask, jnp.exp(s - m_safe[:, None]), 0.0)
    alpha = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - m_safe))
    acc = acc_prev * alpha[:, None] + lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    return m_new, l_prev * alpha + jnp.sum(p, axis=-1), acc


def _paged_kernel(tbl_ref, off_ref, len_ref, ql_ref, q_ref, k_ref, v_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, scale: float, causal: bool,
                  window: int, block_size: int, sq_real: int, rows_real: int,
                  n_tbl: int):
    """Blockspec variant body: one grid step = one table entry."""
    ib = pl.program_id(0)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    off = off_ref[ib]
    kv_end = len_ref[ib]
    q_len = ql_ref[ib]
    # skip table steps with no attendable position: past the row's live
    # length, or (windowed) wholly below every query's window
    live = (t * block_size) < kv_end
    if window > 0:
        live &= (t * block_size + block_size + window) > (off + 1)

    @pl.when(live)
    def _accum():
        m_ref[...], l_ref[...], acc_ref[...] = _accumulate(
            q_ref[0, 0].astype(jnp.float32),
            k_ref[0, :, 0].astype(jnp.float32),
            v_ref[0, :, 0].astype(jnp.float32),
            t, off, kv_end, q_len, m_ref[...], l_ref[...], acc_ref[...],
            scale=scale, causal=causal, window=window, block_size=block_size,
            sq_real=sq_real, rows_real=rows_real)

    @pl.when(t == n_tbl - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = out.astype(o_ref.dtype)


def _paged_kernel_loop(tbl_ref, off_ref, len_ref, ql_ref, q_ref, k_ref,
                       v_ref, o_ref, *, scale: float, causal: bool,
                       window: int, block_size: int, sq_real: int,
                       rows_real: int, rows: int, hd: int):
    """Loop variant body: fori_loop over the row's live table entries."""
    ib = pl.program_id(0)
    ih = pl.program_id(1)
    off = off_ref[ib]
    kv_end = len_ref[ib]
    q_len = ql_ref[ib]
    q = q_ref[0, 0].astype(jnp.float32)

    def body(t, carry):
        m, l, acc = carry
        phys = jnp.maximum(tbl_ref[ib, t], 0)
        k = pl.load(k_ref, (phys, slice(None), ih, slice(None)))
        v = pl.load(v_ref, (phys, slice(None), ih, slice(None)))
        return _accumulate(
            q, k.astype(jnp.float32), v.astype(jnp.float32),
            t, off, kv_end, q_len, m, l, acc, scale=scale, causal=causal,
            window=window, block_size=block_size, sq_real=sq_real,
            rows_real=rows_real)

    t_start = 0
    if window > 0:
        # first table entry any query can still see: qpos_min - window + 1
        t_start = jnp.maximum(off - window + 1, 0) // block_size
    n_live = lax.div(kv_end + block_size - 1, block_size)
    m, l, acc = lax.fori_loop(
        t_start, n_live, body,
        (jnp.full((rows,), NEG_INF, jnp.float32),
         jnp.zeros((rows,), jnp.float32),
         jnp.zeros((rows, hd), jnp.float32)))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def paged_attention_pool(q, k_pool, v_pool, block_tables, kv_offset, kv_len,
                         *, causal: bool = True, window: int = 0,
                         interpret: bool = False, variant: str | None = None,
                         q_lens=None):
    """Core pallas_call. q (b, sq, hq, hd); k/v pool (n_blocks, block_size,
    h_kv, hd); block_tables (b, n_tbl) int32 physical ids (-1 unallocated);
    kv_offset/kv_len (b,) int32. Returns (b, sq, hq, hd).

    ``q_lens (b,)`` (optional) gives each row's real query count for mixed
    ragged waves — positions ``>= q_lens[ib]`` are padding and emit zeros;
    ``None`` means every row uses all ``sq`` positions.

    ``variant`` defaults to "loop" under interpret (CPU) and "blockspec"
    compiled (TPU). Rows whose table holds no live blocks (kv_len 0 / fully
    masked) emit zeros — idle serve cells riding along are discarded
    upstream.
    """
    if variant is None:
        variant = "loop" if interpret else "blockspec"
    b, sq, hq, hd = q.shape
    if q_lens is None:
        q_lens = jnp.full((b,), sq, jnp.int32)
    nb, bs, hkv, _ = k_pool.shape
    n_tbl = block_tables.shape[1]
    g = hq // hkv
    assert hq == hkv * g, (hq, hkv)
    # pack GQA groups as rows: (b, hkv, g*sq, hd), row = ig*sq + iq, then
    # pad the row dim up to the dtype's min sublane tile
    qp = q.transpose(0, 2, 1, 3).reshape(b, hkv, g * sq, hd)
    rows_real = g * sq
    mult = 16 if q.dtype == jnp.bfloat16 else 8
    rows = -(-rows_real // mult) * mult
    if rows != rows_real:
        qp = jnp.pad(qp, ((0, 0), (0, 0), (0, rows - rows_real), (0, 0)))

    common = dict(scale=1.0 / math.sqrt(hd), causal=causal, window=window,
                  block_size=bs, sq_real=sq, rows_real=rows_real)
    if variant == "loop":
        kernel = functools.partial(_paged_kernel_loop, rows=rows, hd=hd,
                                   **common)
        grid_spec = PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(b, hkv),
            in_specs=[
                pl.BlockSpec((1, 1, rows, hd),
                             lambda ib, ih, tbl, off, ln, ql: (ib, ih, 0, 0)),
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec((1, 1, rows, hd),
                                   lambda ib, ih, tbl, off, ln, ql:
                                   (ib, ih, 0, 0)),
        )
    else:
        kernel = functools.partial(_paged_kernel, n_tbl=n_tbl, **common)
        grid_spec = PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(b, hkv, n_tbl),
            in_specs=[
                pl.BlockSpec((1, 1, rows, hd),
                             lambda ib, ih, t, tbl, off, ln, ql:
                             (ib, ih, 0, 0)),
                # the pool indirection: table entry t of row ib names the
                # physical block streamed at grid step (ib, ih, t); -1 clamps
                # to block 0 (its positions are masked via kv_len)
                pl.BlockSpec((1, bs, 1, hd),
                             lambda ib, ih, t, tbl, off, ln, ql:
                             (jnp.maximum(tbl[ib, t], 0), 0, ih, 0)),
                pl.BlockSpec((1, bs, 1, hd),
                             lambda ib, ih, t, tbl, off, ln, ql:
                             (jnp.maximum(tbl[ib, t], 0), 0, ih, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, rows, hd),
                                   lambda ib, ih, t, tbl, off, ln, ql:
                                   (ib, ih, 0, 0)),
            scratch_shapes=[
                VMEM((rows,), jnp.float32),      # running max m
                VMEM((rows,), jnp.float32),      # running denom l
                VMEM((rows, hd), jnp.float32),   # output accumulator
            ],
        )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rows, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), kv_offset.astype(jnp.int32),
      kv_len.astype(jnp.int32), q_lens.astype(jnp.int32), qp, k_pool, v_pool)
    return (out[:, :, :rows_real]
            .reshape(b, hkv, g, sq, hd)
            .transpose(0, 3, 1, 2, 4)
            .reshape(b, sq, hq, hd))
