"""Pure-jnp oracles for the Pallas kernels (single source of truth for the
allclose sweeps in tests/test_kernels_*.py)."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro.models.layers import attention_reference


def flash_attention_ref(q, k, v, *, causal=True, window=0, kv_offset=0,
                        kv_len=None):
    """q/k/v (b, s, h, hd) — direct-softmax oracle (fp32 math)."""
    return attention_reference(q.astype(jnp.float32),
                               k.astype(jnp.float32),
                               v.astype(jnp.float32),
                               causal=causal, window=window,
                               kv_offset=kv_offset, kv_len=kv_len
                               ).astype(q.dtype)


def paged_attention_ref(q, k_pool, v_pool, block_tables, kv_offset, kv_len,
                        *, causal=True, window=0, q_lens=None):
    """Gather-then-attend oracle for the paged kernel (fp32 math).

    Materializes each row's full logical K/V view through its block table
    (the exact path ``blocks.paged_kv_update`` takes) and runs the direct-
    softmax reference over it — the kernel must match this on live
    positions while never building the gathered view. ``q_lens (b,)``
    mirrors the kernel's ragged-wave semantics: query positions past a
    row's real count are zeroed.
    """
    nb, bs = k_pool.shape[0], k_pool.shape[1]
    b = q.shape[0]
    span = (jnp.clip(block_tables, 0, nb - 1)[:, :, None] * bs
            + jnp.arange(bs)[None, None, :]).reshape(b, -1)
    kf = jnp.take(k_pool.reshape(nb * bs, *k_pool.shape[2:]), span, axis=0)
    vf = jnp.take(v_pool.reshape(nb * bs, *v_pool.shape[2:]), span, axis=0)
    out = attention_reference(q.astype(jnp.float32), kf.astype(jnp.float32),
                              vf.astype(jnp.float32), causal=causal,
                              window=window, kv_offset=kv_offset,
                              kv_len=kv_len)
    if q_lens is not None:
        pad = jnp.arange(q.shape[1])[None, :] < q_lens[:, None]
        out = jnp.where(pad[:, :, None, None], out, 0.0)
    return out.astype(q.dtype)


def mamba_scan_ref(da, dbx, cmat, h0):
    """Sequential oracle: h_t = da_t*h + dbx_t; y_t = Σ_n h_t C_t.

    da/dbx (b, s, di, n), cmat (b, s, n), h0 (b, di, n).
    """
    def step(h, inp):
        da_t, dbx_t, c_t = inp
        h = da_t.astype(jnp.float32) * h + dbx_t.astype(jnp.float32)
        y = jnp.sum(h * c_t[:, None, :].astype(jnp.float32), axis=-1)
        return h, y

    h, ys = lax.scan(step, h0.astype(jnp.float32),
                     (da.swapaxes(0, 1), dbx.swapaxes(0, 1),
                      cmat.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(da.dtype), h
