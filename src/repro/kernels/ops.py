"""Jitted dispatch wrappers for the Pallas kernels.

Model code calls these (via ``ModelOptions.use_flash_kernel`` /
``use_mamba_kernel`` / ``use_paged_kernel``); on this CPU container they run
in interpret mode (kernel body executed in Python) — the TPU target compiles
the same pl.pallas_call. Set ``REPRO_PALLAS_INTERPRET=0`` on real TPU.
``paged_attention`` has its own three-way lowering switch
(``REPRO_PAGED_ATTN``) — see the paged section below.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import mamba_scan as ms
from repro.kernels import paged_attention as pa


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# flash attention: Pallas forward + flash-style (chunked, rematerialized)
# jnp backward — pallas_call has no AD rule, and the chunked jnp path is the
# memory-optimal backward anyway (recomputes score blocks from (q, k, v)).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_core(q, k, v, causal, window, kv_offset, block_q, block_k):
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, sk, hd)
    out = fa.flash_attention_bhsd(
        qf, kf, vf, causal=causal, window=window, kv_offset=kv_offset,
        n_q_heads_per_kv=g, block_q=block_q, block_k=block_k,
        interpret=_interpret())
    return out.reshape(b, hq, sq, hd).transpose(0, 2, 1, 3)


def _flash_fwd(q, k, v, causal, window, kv_offset, block_q, block_k):
    return _flash_core(q, k, v, causal, window, kv_offset, block_q,
                       block_k), (q, k, v)


def _flash_bwd(causal, window, kv_offset, block_q, block_k, res, ct):
    from repro.models.layers import chunked_attention
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: chunked_attention(
            q, k, v, causal=causal, window=window, kv_offset=kv_offset,
            q_chunk=max(block_q, 128), kv_chunk=max(block_k, 128)),
        q, k, v)
    return vjp(ct)


_flash_core.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "kv_offset",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    kv_offset: int = 0, kv_len=None,
                    block_q: int = 512, block_k: int = 512):
    """q (b, sq, hq, hd), k/v (b, sk, hkv, hd) -> (b, sq, hq, hd).

    GQA handled in the kernel's index maps. ``kv_len`` (ragged decode) is not
    kernel-supported; callers use the jnp path for ragged decode.
    """
    if kv_len is not None:
        raise NotImplementedError("ragged kv_len uses the jnp path")
    return _flash_core(q, k, v, causal, window, kv_offset, block_q, block_k)


# ---------------------------------------------------------------------------
# paged attention: forward-only (serving decode/append — no AD path needed)
# attention straight from the block pool through per-row block tables. Three
# lowerings, picked by REPRO_PAGED_ATTN or the backend:
#   "pallas"    — compiled Pallas kernel (blockspec variant), the TPU target.
#   "interpret" — the Pallas kernel in interpret mode (loop variant); what
#                 the tier-1 parity tests and forced engine parity runs use.
#                 Interpret-mode pallas_call copies every input buffer per
#                 call (O(pool bytes)), so it is for correctness, not speed.
#   "jnp"       — the kernel's XLA mirror (ref.paged_attention_ref): same
#                 block-table-native math; with engine-trimmed tables it does
#                 O(live_blocks) work. The CPU default — this is what makes
#                 the kernel path outrun the gather path off-TPU.
# ---------------------------------------------------------------------------


def _paged_mode() -> str:
    env = os.environ.get("REPRO_PAGED_ATTN")
    if env in ("pallas", "interpret", "jnp"):
        return env
    if pa.PrefetchScalarGridSpec is None:  # pragma: no cover - very old jax
        return "jnp"
    return "jnp" if jax.default_backend() == "cpu" else "pallas"


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def paged_attention(q, k_pool, v_pool, block_tables, kv_offset, kv_len, *,
                    causal: bool = True, window: int = 0, q_lens=None):
    """q (b, sq, hq, hd); k/v pool (n_blocks, block_size, hkv, hd);
    block_tables (b, n_tbl) int32 (-1 = unallocated); kv_offset/kv_len (b,)
    per-row cache depth / live length. ``q_lens (b,)`` (optional) is each
    row's real query count in a mixed ragged wave — padded positions emit
    zeros. Returns (b, sq, hq, hd).

    GQA, per-row ragged offsets, kv_len masking and the sliding window are
    all handled in-kernel (see kernels/paged_attention.py); the gathered
    ``max_blocks * block_size`` logical view is never materialized by the
    pallas lowerings, and the jnp mirror only materializes the (trimmed)
    table width it is handed.
    """
    mode = _paged_mode()
    if mode == "jnp":
        from repro.kernels.ref import paged_attention_ref
        return paged_attention_ref(q, k_pool, v_pool, block_tables,
                                   kv_offset, kv_len, causal=causal,
                                   window=window, q_lens=q_lens)
    return pa.paged_attention_pool(
        q, k_pool, v_pool, block_tables,
        jnp.asarray(kv_offset, jnp.int32), jnp.asarray(kv_len, jnp.int32),
        causal=causal, window=window, interpret=(mode == "interpret"),
        q_lens=None if q_lens is None else jnp.asarray(q_lens, jnp.int32))


# ---------------------------------------------------------------------------
# mamba selective scan: Pallas forward + sequential jnp backward (a backward
# Pallas kernel — reverse-time scan with the same chunking — is the natural
# next step; the forward is the serving/inference hot spot).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _mamba_core(da, dbx, cmat, h0, chunk, block_di):
    return ms.mamba_scan_bdn(da, dbx, cmat, h0, chunk=chunk,
                             block_di=block_di, interpret=_interpret())


def _mamba_fwd(da, dbx, cmat, h0, chunk, block_di):
    return _mamba_core(da, dbx, cmat, h0, chunk, block_di), \
        (da, dbx, cmat, h0)


def _mamba_bwd(chunk, block_di, res, ct):
    from repro.kernels.ref import mamba_scan_ref
    da, dbx, cmat, h0 = res
    _, vjp = jax.vjp(mamba_scan_ref, da, dbx, cmat, h0)
    return vjp(ct)


_mamba_core.defvjp(_mamba_fwd, _mamba_bwd)


@functools.partial(jax.jit, static_argnames=("chunk", "block_di"))
def mamba_scan(da, dbx, cmat, h0, *, chunk: int = 128, block_di: int = 512):
    """Selective scan: (y, h_final). See mamba_scan.mamba_scan_bdn."""
    di = da.shape[2]
    block = block_di
    while di % block != 0:
        block //= 2
    return _mamba_core(da, dbx, cmat, h0, chunk, max(block, 1))
