"""Fused flash attention for TPU (Pallas): causal / sliding-window, GQA.

TPU-native adaptation (DESIGN.md §2): the online-softmax recurrence is tiled
for VMEM with MXU-aligned blocks (multiples of 128), the kv dimension is the
innermost *sequential* grid axis with fp32 (m, l, acc) VMEM scratch carried
across kv steps, and GQA is expressed in the BlockSpec index maps (each query
head streams its shared kv head's blocks — no materialized repeat_kv).

Layouts: q (BH, Sq, hd), k/v (BKV, Sk, hd) with BH = batch × q_heads and
BKV = batch × kv_heads. ``ops.flash_attention`` handles the (b, s, h, hd) ↔
grid-layout plumbing, padding and interpret-mode dispatch; ``ref.py`` is the
pure-jnp oracle tested against this kernel across shapes/dtypes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; available (and interpretable) on CPU too
    from jax.experimental.pallas import tpu as pltpu
    VMEM = pltpu.VMEM
except Exception:  # pragma: no cover - very old jax
    VMEM = lambda shape, dtype: pl.BlockSpec(memory_space=None)  # noqa: E731

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int, kv_offset: int,
                 sq_real: int, sk_real: int, block_q: int, block_k: int,
                 n_kv_blocks: int):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = q_idx * block_q + lax.broadcasted_iota(jnp.int32, (block_q,
                                                              block_k), 0) \
        + kv_offset
    kpos = kv_idx * block_k + lax.broadcasted_iota(jnp.int32, (block_q,
                                                               block_k), 1)
    mask = (kpos < sk_real) & (qpos < sq_real + kv_offset)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(m_new <= NEG_INF, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF, 0.0, jnp.exp(m_prev - m_safe))
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(kv_idx == n_kv_blocks - 1)
    def _finalize():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         kv_offset: int = 0, n_q_heads_per_kv: int = 1,
                         block_q: int = 512, block_k: int = 512,
                         interpret: bool = False):
    """Core pallas_call. q (BH, Sq, hd); k/v (BKV, Sk, hd), BH = BKV·group."""
    bh, sq, hd = q.shape
    bkv, sk, _ = k.shape
    g = n_q_heads_per_kv
    assert bh == bkv * g, (bh, bkv, g)
    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(sk, 8))
    sq_p = -(-sq // block_q) * block_q
    sk_p = -(-sk // block_k) * block_k
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0)))
    n_q = sq_p // block_q
    n_k = sk_p // block_k
    grid = (bh, n_q, n_k)

    kernel = functools.partial(
        _attn_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
        window=window, kv_offset=kv_offset, sq_real=sq, sk_real=sk,
        block_q=block_q, block_k=block_k, n_kv_blocks=n_k)

    # GQA in the index maps: query head i streams kv head i // g. The kv/v
    # blocks of one kv head are re-read by its g query heads (VMEM-resident
    # per grid step — no materialized repeat_kv in HBM).
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda i, j, t: (i, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda i, j, t: (i // g, t, 0)),
            pl.BlockSpec((1, block_k, hd), lambda i, j, t: (i // g, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda i, j, t: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_p, hd), q.dtype),
        scratch_shapes=[
            VMEM((block_q,), jnp.float32),   # running max m
            VMEM((block_q,), jnp.float32),   # running denom l
            VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
