"""Per-family decoder/encoder blocks with functional KV/SSM cache threading.

A *block* is one layer of the stack: pre-norm mixer + pre-norm FFN with
residuals. Signature convention (used by the stacked scan in ``lm.py`` and by
the Hydra pipeline engine):

    y, new_cache = block_apply(cfg, opts, p, x, pos=..., cache=..., mode=...)

``cache`` is this layer's cache slice (or None in train mode); ``pos`` carries
position ids — (b, s) int32 for rope-1d/2d, (3, b, s) for M-RoPE. In decode
mode ``kv_offset`` (b,) gives the current cache length per sequence.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.layers import ModelOptions


# ---------------------------------------------------------------------------
# Attention sub-block (shared by dense / moe / audio / vlm / encoder / hybrid)
# ---------------------------------------------------------------------------


def paged_kv_scatter(cache, k, v, block_tables, kv_offset, write_mask=None):
    """Scatter a (b, s) chunk of new K/V into the shared block pool.

    cache {'k','v'}: (n_blocks, block_size, h_kv, hd) — the *pool*, shared by
    every row (no batch axis). block_tables (b, max_blocks) int32 physical ids
    local to this shard's pool slice, -1 = unallocated. kv_offset (b,) is the
    row's cache depth (tokens already written). ``write_mask`` is (b,) rows
    or (b, s) per-token (mixed ragged waves mask each row's padded tail).
    Masked entries — idle cells riding along, pipeline bubble ticks, or
    ragged query padding — write nothing
    (their scatter indices are pushed out of bounds and dropped); the
    allocator guarantees live rows' blocks are disjoint, so the scatters
    never collide. Tokens past table capacity (``pos // bs >= max_blocks``)
    are dropped too — clipping the block index instead would alias them onto
    the row's *last* allocated block (the clipped entry holds a valid
    physical id, so the ``phys >= 0`` check alone lets the write land) and
    silently corrupt cached K/V. Returns the updated pool.
    """
    b, s = k.shape[0], k.shape[1]
    nb, bs = cache["k"].shape[0], cache["k"].shape[1]
    max_blocks = block_tables.shape[1]
    pool_k = cache["k"].reshape(nb * bs, *cache["k"].shape[2:])
    pool_v = cache["v"].reshape(nb * bs, *cache["v"].shape[2:])
    # scatter the chunk: token i of row r lands in block table[r, p//bs] at
    # in-block slot p%bs, p = kv_offset[r] + i
    pos = kv_offset[:, None] + jnp.arange(s)[None, :]  # (b, s)
    blk = jnp.clip(pos // bs, 0, max_blocks - 1)
    phys = jnp.take_along_axis(block_tables, blk, axis=1)  # (b, s)
    ok = (phys >= 0) & (pos // bs < max_blocks)
    if write_mask is not None:
        ok = ok & (write_mask if write_mask.ndim == 2 else write_mask[:, None])
    flat = jnp.where(ok, phys * bs + pos % bs, nb * bs)  # OOB -> dropped
    pool_k = pool_k.at[flat.reshape(-1)].set(
        k.reshape(b * s, *k.shape[2:]).astype(pool_k.dtype), mode="drop")
    pool_v = pool_v.at[flat.reshape(-1)].set(
        v.reshape(b * s, *v.shape[2:]).astype(pool_v.dtype), mode="drop")
    return {"k": pool_k.reshape(cache["k"].shape),
            "v": pool_v.reshape(cache["v"].shape)}


def paged_kv_update(cache, k, v, block_tables, kv_offset, write_mask=None):
    """Scatter (see :func:`paged_kv_scatter`) and gather each row's full
    logical cache view back out through its table.

    Returns (new_cache, k_rows, v_rows) where k_rows/v_rows are
    (b, max_blocks*block_size, h_kv, hd) gathered views whose garbage tail
    (unallocated blocks / stale tokens) the caller masks via kv_len. This is
    the *gather path* — O(max_blocks·block_size) materialized per row per
    call; the paged kernel path (``opts.use_paged_kernel``) scatters only and
    attends straight from the pool.
    """
    b = k.shape[0]
    nb, bs = cache["k"].shape[0], cache["k"].shape[1]
    max_blocks = block_tables.shape[1]
    new_cache = paged_kv_scatter(cache, k, v, block_tables, kv_offset,
                                 write_mask)
    pool_k = new_cache["k"].reshape(nb * bs, *cache["k"].shape[2:])
    pool_v = new_cache["v"].reshape(nb * bs, *cache["v"].shape[2:])
    # gather each row's logical view: position j reads block table[r, j//bs]
    span = (jnp.clip(block_tables, 0, nb - 1)[:, :, None] * bs
            + jnp.arange(bs)[None, None, :]).reshape(b, max_blocks * bs)
    k_rows = jnp.take(pool_k, span, axis=0)
    v_rows = jnp.take(pool_v, span, axis=0)
    return new_cache, k_rows, v_rows


def attn_apply(cfg: ArchConfig, opts: ModelOptions, p, x, *, pos,
               cache=None, kv_offset=None, mode: str = "train",
               window: int = 0, causal: bool = True, block_tables=None,
               write_mask=None, q_lens=None):
    """x (b, s, d) -> (b, s, d); cache {'k','v'}: (b, S_max, h_kv, hd).

    ``block_tables`` switches the append/decode cache handling to the paged
    pool layout (see :func:`paged_kv_update`): cache is then the shared
    (n_blocks, block_size, h_kv, hd) pool and ``write_mask`` gates which rows
    may write this call.

    ``q_lens (b,)`` activates the mixed-tick ragged-wave semantics in append
    mode: each row's real query count (chunk width for prefilling cells, 1
    for decoding cells, 0 for idle), with positions past it padding — never
    written to the cache, attending to nothing. A decode row is exactly the
    ``q_lens = 1`` case of append, so one program serves both phases.
    """
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(b, s, hkv, hd)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(b, s, hkv, hd)
    q = L.apply_rope(q, pos, cfg)
    k = L.apply_rope(k, pos, cfg)
    new_cache = cache
    if mode == "train":
        out = L.attention(q, k, v, causal=causal, window=window, opts=opts)
    elif mode == "prefill":
        # write k/v into the cache (offset 0); windowed caches keep the tail
        s_cache = cache["k"].shape[1]
        if s >= s_cache:
            kw, vw = k[:, -s_cache:], v[:, -s_cache:]
            pad = 0
        else:
            kw, vw, pad = k, v, s_cache - s
        new_cache = {
            "k": jnp.pad(kw, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(
                cache["k"].dtype),
            "v": jnp.pad(vw, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(
                cache["v"].dtype),
        }
        out = L.attention(q, k, v, causal=causal, window=window, opts=opts)
    elif mode == "append" and block_tables is not None:
        # paged chunked prefill: same semantics as the dense append below but
        # K/V live in the shared block pool, reached through per-row tables
        cap = block_tables.shape[1] * cache["k"].shape[1]
        kv_len = jnp.minimum(kv_offset + (s if q_lens is None else q_lens),
                             cap)
        wm = write_mask
        if q_lens is not None:
            # mixed ragged wave: only each row's first q_lens tokens are real
            tok = jnp.arange(s)[None, :] < q_lens[:, None]
            if wm is not None:
                tok = tok & (wm if wm.ndim == 2 else wm[:, None])
            wm = tok
        if opts.use_paged_kernel:
            # scatter only — the kernel attends straight from the pool
            # through the tables, never building the gathered view
            from repro.kernels import ops as kernel_ops
            new_cache = paged_kv_scatter(cache, k, v, block_tables,
                                         kv_offset, wm)
            out = kernel_ops.paged_attention(
                q, new_cache["k"], new_cache["v"], block_tables, kv_offset,
                kv_len, causal=True, window=window, q_lens=q_lens)
        else:
            new_cache, kf, vf = paged_kv_update(cache, k, v, block_tables,
                                                kv_offset, wm)
            out = L.attention(
                q, kf.astype(q.dtype), vf.astype(q.dtype),
                causal=True, window=window, kv_offset=kv_offset,
                kv_len=kv_len, opts=opts)
    elif mode == "append":
        # chunked prefill: insert a whole chunk at kv_offset and attend over
        # the cache prefix + causally within the chunk (kv_offset handles the
        # relative positions). kv_offset is per-row (b,) — rows may sit at
        # different cache depths (continuous-batching admission chunks).
        s_cache = cache["k"].shape[1]
        if q_lens is not None:
            # mixed ragged wave: rows are padded to the wave max, and
            # ``dynamic_update_slice`` CLAMPS out-of-range starts — a decode
            # row near the strip end would have its padded write shifted
            # backwards over real history. Scatter per token instead,
            # dropping padded and out-of-strip targets.
            tgt = kv_offset[:, None] + jnp.arange(s)[None, :]  # (b, s)
            ok = (jnp.arange(s)[None, :] < q_lens[:, None]) & (tgt < s_cache)
            if write_mask is not None:
                ok = ok & (write_mask if write_mask.ndim == 2
                           else write_mask[:, None])
            flat = jnp.where(ok, jnp.arange(b)[:, None] * s_cache + tgt,
                             b * s_cache)  # OOB -> dropped

            def scat(c, t):
                cf = c.reshape(b * s_cache, *c.shape[2:])
                cf = cf.at[flat.reshape(-1)].set(
                    t.reshape(b * s, *t.shape[2:]).astype(c.dtype),
                    mode="drop")
                return cf.reshape(c.shape)
            new_cache = {"k": scat(cache["k"], k), "v": scat(cache["v"], v)}
            kv_len = jnp.minimum(kv_offset + q_lens, s_cache)
        else:
            def updm(c, t, o):
                return lax.dynamic_update_slice(c, t.astype(c.dtype),
                                                (o, 0, 0))
            new_cache = {
                "k": jax.vmap(updm)(cache["k"], k, kv_offset),
                "v": jax.vmap(updm)(cache["v"], v, kv_offset),
            }
            kv_len = jnp.minimum(kv_offset + s, s_cache)
        out = L.attention(
            q, new_cache["k"].astype(q.dtype), new_cache["v"].astype(q.dtype),
            causal=True, window=window, kv_offset=kv_offset,
            kv_len=kv_len, opts=opts)
    elif mode == "decode" and block_tables is not None:
        # paged decode: one-token append through the table, then the same
        # masked-full-cache attention the dense decode runs; window > 0
        # additionally masks positions <= pos - window (the gathered view is
        # in absolute logical layout, so the positional mask is exact)
        cap = block_tables.shape[1] * cache["k"].shape[1]
        kv_len = jnp.minimum(kv_offset + 1, cap)
        if opts.use_paged_kernel:
            # kernel decode is causal with per-row offsets: at sq=1 the mask
            # kpos <= kv_offset & kpos < kv_len equals the gather path's
            # causal=False kv_len-only mask
            from repro.kernels import ops as kernel_ops
            new_cache = paged_kv_scatter(cache, k, v, block_tables,
                                         kv_offset, write_mask)
            out = kernel_ops.paged_attention(
                q, new_cache["k"], new_cache["v"], block_tables, kv_offset,
                kv_len, causal=True, window=window)
        elif window > 0:
            new_cache, kf, vf = paged_kv_update(cache, k, v, block_tables,
                                                kv_offset, write_mask)
            out = L.attention(
                q, kf.astype(q.dtype), vf.astype(q.dtype),
                causal=True, window=window, kv_offset=kv_offset,
                kv_len=kv_len, opts=opts)
        else:
            new_cache, kf, vf = paged_kv_update(cache, k, v, block_tables,
                                                kv_offset, write_mask)
            out = L.attention(
                q, kf.astype(q.dtype), vf.astype(q.dtype),
                causal=False, window=0, kv_offset=0, kv_len=kv_len, opts=opts)
    elif mode == "decode":
        # ring-buffer insert: slot = kv_offset mod cache_len (identity for
        # unwindowed caches, rolling slot for sliding-window caches)
        s_cache = cache["k"].shape[1]
        slot = kv_offset % s_cache

        def upd(c, t, o):
            return lax.dynamic_update_slice(c, t.astype(c.dtype), (o, 0, 0))
        new_cache = {
            "k": jax.vmap(upd)(cache["k"], k, slot),
            "v": jax.vmap(upd)(cache["v"], v, slot),
        }
        kv_len = jnp.minimum(kv_offset + 1, s_cache)
        if window > 0 and s_cache > window:
            # absolute-layout cache wider than the window (continuous-batching
            # serving keeps max_seq strips): mask positions <= pos - window.
            # When s_cache <= window the ring itself enforces the window (the
            # static long-context path) and every live row is attendable.
            out = L.attention(
                q, new_cache["k"].astype(q.dtype),
                new_cache["v"].astype(q.dtype), causal=True, window=window,
                kv_offset=kv_offset, kv_len=kv_len, opts=opts)
        else:
            out = L.attention(
                q, new_cache["k"].astype(q.dtype),
                new_cache["v"].astype(q.dtype),
                causal=False, window=0, kv_offset=0, kv_len=kv_len, opts=opts)
    else:
        raise ValueError(mode)
    out = out.reshape(b, s, h * hd)
    return jnp.einsum("bse,ed->bsd", out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# Family blocks
# ---------------------------------------------------------------------------


def dense_block(cfg, opts, p, x, *, pos, cache=None, kv_offset=None,
                mode="train", window: int = 0, block_tables=None,
                write_mask=None, q_lens=None):
    causal = cfg.family != "encoder"
    if cfg.family == "encoder":
        h = L.layer_norm(x, p["ln1_w"], p["ln1_b"], cfg.norm_eps)
    else:
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    a, new_cache = attn_apply(cfg, opts, p["attn"], h, pos=pos, cache=cache,
                              kv_offset=kv_offset, mode=mode, window=window,
                              causal=causal, block_tables=block_tables,
                              write_mask=write_mask, q_lens=q_lens)
    x = x + a
    if cfg.family == "encoder":
        h = L.layer_norm(x, p["ln2_w"], p["ln2_b"], cfg.norm_eps)
    else:
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + L.mlp_apply(p["mlp"], h, cfg.act)
    return x, new_cache, jnp.zeros((), jnp.float32)


def moe_block(cfg, opts, p, x, *, pos, cache=None, kv_offset=None,
              mode="train", window: int = 0, block_tables=None,
              write_mask=None, q_lens=None):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    a, new_cache = attn_apply(cfg, opts, p["attn"], h, pos=pos, cache=cache,
                              kv_offset=kv_offset, mode=mode, window=window,
                              block_tables=block_tables,
                              write_mask=write_mask, q_lens=q_lens)
    x = x + a
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    m, aux = L.moe_apply(p["moe"], h, n_experts=cfg.moe.n_experts,
                         top_k=cfg.moe.top_k,
                         capacity_factor=opts.moe_capacity_factor,
                         act=cfg.act, expert_chunk=opts.moe_expert_chunk)
    return x + m, new_cache, aux


def ssm_block(cfg, opts, p, x, *, pos, cache=None, kv_offset=None,
              mode="train", window: int = 0, block_tables=None,
              write_mask=None, q_lens=None):
    """Mamba1 block (falcon-mamba): norm -> mamba -> residual.
    (``block_tables``/``write_mask``/``q_lens`` are accepted for signature
    uniformity; recurrent state is O(1) per row and never paged, and ragged
    mixed waves are attention-family only — padded tokens would advance the
    recurrent state.)"""
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    ssm_s = cache["ssm"] if cache is not None else None
    conv_s = cache["conv"] if cache is not None else None
    y, new_ssm, new_conv = L.mamba1_mix(p["mamba"], h, cfg, ssm_s, conv_s,
                                        opts)
    new_cache = None
    if cache is not None:
        new_cache = {"ssm": new_ssm, "conv": new_conv.astype(cache["conv"].dtype)}
    return x + y, new_cache, jnp.zeros((), jnp.float32)


def hybrid_backbone_block(cfg, opts, p, x, *, pos, cache=None, kv_offset=None,
                          mode="train", window: int = 0, block_tables=None,
                          write_mask=None, q_lens=None):
    """Zamba2 backbone layer: Mamba2 mixer. (Paging kwargs unused: the
    recurrent state is O(1) per row.)"""
    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    ssm_s = cache["ssm"] if cache is not None else None
    conv_s = cache["conv"] if cache is not None else None
    y, new_ssm, new_conv = L.mamba2_mix(p["mamba"], h, cfg, ssm_s, conv_s,
                                        opts)
    new_cache = None
    if cache is not None:
        new_cache = {"ssm": new_ssm, "conv": new_conv.astype(cache["conv"].dtype)}
    return x + y, new_cache, jnp.zeros((), jnp.float32)


def shared_attn_block(cfg, opts, p, x, *, pos, cache=None, kv_offset=None,
                      mode="train", window: int = 0):
    """Zamba2's shared attention+MLP block (weights shared across sites)."""
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    a, new_cache = attn_apply(cfg, opts, p["attn"], h, pos=pos, cache=cache,
                              kv_offset=kv_offset, mode=mode, window=window)
    x = x + a
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    x = x + L.mlp_apply(p["mlp"], h, "swiglu")
    return x, new_cache


BLOCK_FNS = {
    "dense": dense_block,
    "audio": dense_block,
    "vlm": dense_block,
    "encoder": dense_block,
    "moe": moe_block,
    "ssm": ssm_block,
    "hybrid": hybrid_backbone_block,
}


def block_fn_for(cfg: ArchConfig):
    return BLOCK_FNS[cfg.family]


# ---------------------------------------------------------------------------
# Per-layer cache structure (shapes only — used for init and dry-run specs)
# ---------------------------------------------------------------------------


def layer_cache_shape(cfg: ArchConfig, batch: int, max_seq: int,
                      cache_dtype=jnp.bfloat16) -> dict:
    """Shape/dtype template for ONE layer's cache (no leading layer dim)."""
    if cfg.family == "ssm":
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        return {
            "ssm": jax.ShapeDtypeStruct((batch, di, s.d_state), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, s.d_conv - 1, di), cache_dtype),
        }
    if cfg.family == "hybrid":
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        nh = s.n_ssm_heads(cfg.d_model)
        conv_dim = di + 2 * s.n_groups * s.d_state
        return {
            "ssm": jax.ShapeDtypeStruct(
                (batch, nh, s.head_dim, s.d_state), jnp.float32),
            "conv": jax.ShapeDtypeStruct(
                (batch, s.d_conv - 1, conv_dim), cache_dtype),
        }
    return {
        "k": jax.ShapeDtypeStruct(
            (batch, max_seq, cfg.n_kv_heads, cfg.head_dim), cache_dtype),
        "v": jax.ShapeDtypeStruct(
            (batch, max_seq, cfg.n_kv_heads, cfg.head_dim), cache_dtype),
    }


def shared_cache_shape(cfg: ArchConfig, batch: int, max_seq: int,
                       cache_dtype=jnp.bfloat16,
                       window: int = 0) -> Optional[dict]:
    """Cache template for ONE shared-attention site (hybrid archs).

    ``window`` > 0 (long-context serving) bounds the cache to the sliding
    window; the engine activates it only for the long_500k shape.
    """
    if cfg.hybrid is None:
        return None
    seq = min(max_seq, window) if window > 0 else max_seq
    return {
        "k": jax.ShapeDtypeStruct(
            (batch, seq, cfg.n_kv_heads, cfg.head_dim), cache_dtype),
        "v": jax.ShapeDtypeStruct(
            (batch, seq, cfg.n_kv_heads, cfg.head_dim), cache_dtype),
    }
