"""Stacked-layer language models: init, forward, decode — pure JAX.

The layer stack is stored with a leading layer axis on every leaf so that
(a) ``lax.scan`` applies layers with O(1) HLO size, and (b) the Hydra pipeline
engine can shard that axis across pipeline stages (`PartitionSpec('model', …)`)
and run a *contiguous slice* of layers per stage via the same ``stack_apply``.

``stack_apply`` therefore takes a per-layer validity ``mask`` (stages pad the
layer count to stages × layers_per_stage) and, for hybrid archs, per-layer
shared-attention site flags. Single-device forward (= the exactness oracle) is
just ``stack_apply`` over all layers with mask all-true.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import blocks as B
from repro.models import layers as L
from repro.models.layers import ModelOptions


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def _normal(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape) / math.sqrt(max(fan_in, 1))).astype(dtype)


def init_attn_params(cfg: ArchConfig, key, dtype):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": _normal(k1, (d, h * hd), d, dtype),
        "wk": _normal(k2, (d, hkv * hd), d, dtype),
        "wv": _normal(k3, (d, hkv * hd), d, dtype),
        "wo": _normal(k4, (h * hd, d), h * hd, dtype),
    }


def init_mlp_params(d: int, f: int, act: str, key, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "swiglu":
        return {"w_gate": _normal(k1, (d, f), d, dtype),
                "w_up": _normal(k2, (d, f), d, dtype),
                "w_down": _normal(k3, (f, d), f, dtype)}
    return {"w_up": _normal(k1, (d, f), d, dtype),
            "w_down": _normal(k2, (f, d), f, dtype)}


def init_layer_params(cfg: ArchConfig, key, dtype):
    """One layer of the stack (no leading layer dim)."""
    d = cfg.d_model
    if cfg.family == "ssm":
        s = cfg.ssm
        di, n = s.d_inner(d), s.d_state
        r = s.resolved_dt_rank(d)
        ks = jax.random.split(key, 6)
        # dt bias ~ softplus^-1 of dt in [1e-3, 1e-1] (mamba init)
        u = jax.random.uniform(ks[5], (di,), minval=math.log(1e-3),
                               maxval=math.log(1e-1))
        dt = jnp.exp(u)
        dt_bias = dt + jnp.log1p(-jnp.exp(-dt))
        return {
            "ln": jnp.ones((d,), dtype),
            "mamba": {
                "in_proj": _normal(ks[0], (d, 2 * di), d, dtype),
                "conv_w": _normal(ks[1], (di, s.d_conv), s.d_conv, dtype),
                "conv_b": jnp.zeros((di,), dtype),
                "x_proj": _normal(ks[2], (di, r + 2 * n), di, dtype),
                "dt_proj": _normal(ks[3], (r, di), r, dtype),
                "dt_bias": dt_bias.astype(jnp.float32),
                "A_log": jnp.log(jnp.broadcast_to(
                    jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
                "D": jnp.ones((di,), jnp.float32),
                "out_proj": _normal(ks[4], (di, d), di, dtype),
            },
        }
    if cfg.family == "hybrid":
        s = cfg.ssm
        di, n, g = s.d_inner(d), s.d_state, s.n_groups
        nh = s.n_ssm_heads(d)
        conv_dim = di + 2 * g * n
        ks = jax.random.split(key, 4)
        u = jax.random.uniform(ks[3], (nh,), minval=math.log(1e-3),
                               maxval=math.log(1e-1))
        dt = jnp.exp(u)
        dt_bias = dt + jnp.log1p(-jnp.exp(-dt))
        return {
            "ln": jnp.ones((d,), dtype),
            "mamba": {
                "in_proj": _normal(ks[0], (d, 2 * di + 2 * g * n + nh), d, dtype),
                "conv_w": _normal(ks[1], (conv_dim, s.d_conv), s.d_conv, dtype),
                "conv_b": jnp.zeros((conv_dim,), dtype),
                "dt_bias": dt_bias.astype(jnp.float32),
                "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
                "D": jnp.ones((nh,), jnp.float32),
                "norm_w": jnp.ones((di,), dtype),
                "out_proj": _normal(ks[2], (di, d), di, dtype),
            },
        }
    # attention families
    k_attn, k_mlp, k_moe = jax.random.split(key, 3)
    p = {"attn": init_attn_params(cfg, k_attn, dtype)}
    if cfg.family == "encoder":
        p["ln1_w"] = jnp.ones((cfg.d_model,), dtype)
        p["ln1_b"] = jnp.zeros((cfg.d_model,), dtype)
        p["ln2_w"] = jnp.ones((cfg.d_model,), dtype)
        p["ln2_b"] = jnp.zeros((cfg.d_model,), dtype)
    else:
        p["ln1"] = jnp.ones((cfg.d_model,), dtype)
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.family == "moe":
        e, fe = cfg.moe.n_experts, cfg.moe.expert_d_ff
        km = jax.random.split(k_moe, 4)
        p["moe"] = {
            "router": _normal(km[0], (d, e), d, dtype),
            "w_gate": _normal(km[1], (e, d, fe), d, dtype),
            "w_up": _normal(km[2], (e, d, fe), d, dtype),
            "w_down": _normal(km[3], (e, fe, d), fe, dtype),
        }
    else:
        p["mlp"] = init_mlp_params(d, cfg.d_ff, cfg.act, k_mlp, dtype)
    return p


def init_shared_params(cfg: ArchConfig, key, dtype):
    if cfg.hybrid is None:
        return None
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attn_params(cfg, k1, dtype),
        "mlp": init_mlp_params(cfg.d_model, cfg.hybrid.shared_d_ff, "swiglu",
                               k2, dtype),
    }


def init_params(cfg: ArchConfig, key, dtype=jnp.float32, max_pos: int = 0,
                n_layers: Optional[int] = None):
    """Full model pytree. Layer leaves get a leading ``n_layers`` axis.

    ``n_layers`` may exceed ``cfg.n_layers`` (stage padding); padded layers
    get ordinary init but are masked out at apply time.
    """
    nl = n_layers or cfg.n_layers
    k_emb, k_layers, k_shared, k_head, k_pos = jax.random.split(key, 5)
    layer_keys = jax.random.split(k_layers, nl)
    layers = jax.vmap(lambda k: init_layer_params(cfg, k, dtype))(layer_keys)
    params = {
        "embed": {"tok": _normal(k_emb, (cfg.vocab_size, cfg.d_model), 1, dtype)},
        "layers": layers,
        "final_norm": (
            {"w": jnp.ones((cfg.d_model,), dtype),
             "b": jnp.zeros((cfg.d_model,), dtype)}
            if cfg.family == "encoder" else jnp.ones((cfg.d_model,), dtype)),
    }
    if cfg.rope == "learned":
        params["embed"]["pos"] = _normal(k_pos, (max(max_pos, 1), cfg.d_model),
                                         1, dtype)
    if cfg.hybrid is not None:
        params["shared"] = init_shared_params(cfg, k_shared, dtype)
    if not cfg.tie_embeddings:
        params["head"] = _normal(k_head, (cfg.d_model, cfg.vocab_size),
                                 cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------
# Shared-site bookkeeping (hybrid archs)
# ---------------------------------------------------------------------------


def shared_site_flags(cfg: ArchConfig, layer_offset: int, n_local: int):
    """(use_shared, site_slot) int arrays for layers [offset, offset+n_local).

    ``site_slot`` is the *local* slot index within this stage's shared-cache
    buffer (sequential over the stage's flagged layers).
    """
    if cfg.hybrid is None:
        return (jnp.zeros((n_local,), bool), jnp.zeros((n_local,), jnp.int32))
    gidx = layer_offset + jnp.arange(n_local)  # offset may be traced (stage id)
    flags = ((gidx + 1) % cfg.hybrid.attn_every == 0) & (gidx < cfg.n_layers)
    slots = jnp.cumsum(flags.astype(jnp.int32)) - 1
    return flags, jnp.maximum(slots, 0)


def n_shared_sites(cfg: ArchConfig, layer_offset: int = 0,
                   n_local: Optional[int] = None) -> int:
    if cfg.hybrid is None:
        return 0
    n_local = n_local if n_local is not None else cfg.n_layers
    count = 0
    for g in range(layer_offset, layer_offset + n_local):
        if (g + 1) % cfg.hybrid.attn_every == 0 and g < cfg.n_layers:
            count += 1
    return max(count, 1)


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------


def _zeros_like_spec(tree):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), tree)


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               cache_dtype=jnp.bfloat16, n_layers: Optional[int] = None,
               window: int = 0):
    """Stacked per-layer cache (leading layer axis) + shared-site cache."""
    nl = n_layers or cfg.n_layers
    one = B.layer_cache_shape(cfg, batch, max_seq, cache_dtype)
    stacked = jax.tree.map(
        lambda s: jnp.zeros((nl,) + s.shape, s.dtype), one)
    shared = None
    if cfg.hybrid is not None:
        s_one = B.shared_cache_shape(cfg, batch, max_seq, cache_dtype, window)
        ns = n_shared_sites(cfg)
        shared = jax.tree.map(
            lambda s: jnp.zeros((ns,) + s.shape, s.dtype), s_one)
    return {"layers": stacked, "shared": shared}


def cache_specs(cfg: ArchConfig, batch: int, max_seq: int,
                cache_dtype=jnp.bfloat16, n_layers: Optional[int] = None,
                window: int = 0, n_shared_slots: Optional[int] = None):
    """ShapeDtypeStruct view of ``init_cache`` (dry-run, no allocation)."""
    nl = n_layers or cfg.n_layers
    one = B.layer_cache_shape(cfg, batch, max_seq, cache_dtype)
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((nl,) + s.shape, s.dtype), one)
    shared = None
    if cfg.hybrid is not None:
        s_one = B.shared_cache_shape(cfg, batch, max_seq, cache_dtype, window)
        ns = n_shared_slots if n_shared_slots is not None else n_shared_sites(cfg)
        shared = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((ns,) + s.shape, s.dtype), s_one)
    return {"layers": stacked, "shared": shared}


# ---------------------------------------------------------------------------
# Stacked layer application (the unit the pipeline engine runs per stage)
# ---------------------------------------------------------------------------


def stack_apply(cfg: ArchConfig, opts: ModelOptions, layer_params, x, *,
                pos, mode: str = "train", cache=None, shared_params=None,
                shared_cache=None, layer_mask=None, layer_offset=0,
                kv_offset=None, window: int = 0, layer_param_fn=None,
                inner_remat=None, block_tables=None, write_mask=None,
                q_lens=None):
    """Apply a contiguous slice of the layer stack.

    layer_params: pytree with leading local-layer axis (n_local, ...).
    cache:        {"layers": stacked cache or None, "shared": site cache}.
    layer_mask:   (n_local,) bool — False = padded no-op layer.
    layer_param_fn: optional hook applied to each layer's params inside the
        scan body (the pipeline engine uses it for per-layer FSDP all-gather).
    ``layer_offset`` may be a traced scalar (stage_id * layers_per_stage).
    ``block_tables``/``write_mask`` switch append/decode attention to the
    paged cache layout (cache["layers"] then stacks per-layer block *pools*
    with no batch axis — see ``blocks.paged_kv_update``); ``q_lens (b,)``
    carries per-row real query counts for mixed ragged append waves.
    Returns (y, new_cache, aux_loss_sum).
    """
    n_local = jax.tree.leaves(layer_params)[0].shape[0]
    if layer_mask is None:
        layer_mask = jnp.ones((n_local,), bool)
    use_shared, site_slot = shared_site_flags(cfg, layer_offset, n_local)
    block = B.block_fn_for(cfg)
    layer_cache = cache["layers"] if cache is not None else None
    sh_cache = cache["shared"] if cache is not None else shared_cache
    has_cache = layer_cache is not None

    def body(carry, xs):
        xc, shc, aux = carry
        if has_cache:
            p_i, m_i, us_i, slot_i, c_i = xs
        else:
            p_i, m_i, us_i, slot_i = xs
            c_i = None
        if layer_param_fn is not None:
            p_i = layer_param_fn(p_i)

        def run(operand):
            xc, shc, c_i = operand
            y, new_c, aux_i = block(cfg, opts, p_i, xc, pos=pos, cache=c_i,
                                    kv_offset=kv_offset, mode=mode,
                                    window=window, block_tables=block_tables,
                                    write_mask=write_mask, q_lens=q_lens)
            if shared_params is not None:
                def run_shared(op):
                    y, shc = op
                    sc_i = None
                    if shc is not None:
                        sc_i = jax.tree.map(lambda c: c[slot_i], shc)
                    y2, new_sc = B.shared_attn_block(
                        cfg, opts, shared_params, y, pos=pos, cache=sc_i,
                        kv_offset=kv_offset, mode=mode, window=window)
                    if shc is not None:
                        shc = jax.tree.map(
                            lambda c, n: lax.dynamic_update_index_in_dim(
                                c, n.astype(c.dtype), slot_i, 0),
                            shc, new_sc)
                    return y2, shc

                y, shc2 = lax.cond(us_i, run_shared, lambda op: op, (y, shc))
            else:
                shc2 = shc
            return y, shc2, (new_c if new_c is not None else c_i), aux_i

        def skip(operand):
            xc, shc, c_i = operand
            return xc, shc, c_i, jnp.zeros((), jnp.float32)

        y, shc_new, c_new, aux_i = lax.cond(m_i, run, skip, (xc, shc, c_i))
        return (y, shc_new, aux + aux_i), c_new

    do_remat = opts.remat if inner_remat is None else inner_remat
    body_fn = jax.checkpoint(body) if (do_remat and mode == "train") else body
    xs = (layer_params, layer_mask, use_shared, site_slot)
    if has_cache:
        xs = xs + (layer_cache,)
    (y, sh_new, aux), cache_new = lax.scan(body_fn, (x, sh_cache, 0.0), xs)
    out_cache = None
    if has_cache:
        out_cache = {"layers": cache_new, "shared": sh_new}
    return y, out_cache, aux


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ArchConfig, p_embed, tokens, *, positions=None,
                 frontend_embeds=None, compute_dtype=None):
    x = jnp.take(p_embed["tok"], tokens, axis=0)
    if compute_dtype is not None:
        x = x.astype(compute_dtype)
    if cfg.rope == "learned" and positions is not None:
        pos_table = p_embed["pos"]
        x = x + jnp.take(pos_table, jnp.minimum(positions, pos_table.shape[0] - 1),
                         axis=0).astype(x.dtype)
    if frontend_embeds is not None:
        nf = frontend_embeds.shape[1]
        x = x.at[:, :nf].set(frontend_embeds.astype(x.dtype))
    return x


def final_norm_apply(cfg: ArchConfig, p_norm, x):
    if cfg.family == "encoder":
        return L.layer_norm(x, p_norm["w"], p_norm["b"], cfg.norm_eps)
    return L.rms_norm(x, p_norm, cfg.norm_eps)


def lm_logits(cfg: ArchConfig, params, x):
    x = final_norm_apply(cfg, params["final_norm"], x)
    head = params.get("head")
    if head is None:  # tied embeddings
        head = params["embed"]["tok"].T
    return jnp.einsum("bsd,dv->bsv", x, head)


def cross_entropy(logits, labels, mask=None):
    """Mean CE over unmasked positions; fp32 accumulation."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Whole-model entry points (single-device oracle; smoke tests; examples)
# ---------------------------------------------------------------------------


def default_positions(cfg: ArchConfig, batch: dict, b: int, s: int):
    if cfg.rope == "mrope":
        if "mrope_pos" in batch:
            return batch["mrope_pos"]
        base = jnp.broadcast_to(jnp.arange(s), (b, s))
        return jnp.broadcast_to(base, (3, b, s))
    return jnp.broadcast_to(jnp.arange(s), (b, s))


def forward(cfg: ArchConfig, opts: ModelOptions, params, batch: dict,
            mode: str = "train", cache=None, kv_offset=None, window: int = 0,
            layer_mask=None):
    """Full-model forward. Returns (logits, new_cache, aux).

    ``layer_mask`` supports stage-padded stacks (leaves longer than
    cfg.n_layers); defaults to masking exactly the real layers.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    if mode == "decode":
        pos = kv_offset[:, None]  # (b, 1) absolute positions
        if cfg.rope == "mrope":
            pos = jnp.broadcast_to(pos, (3, b, 1))
    else:
        pos = default_positions(cfg, batch, b, s)
    x = embed_tokens(cfg, params["embed"], tokens,
                     positions=pos if cfg.rope != "mrope" else None,
                     frontend_embeds=batch.get("frontend_embeds"),
                     compute_dtype=opts.compute_dtype)
    n_stack = jax.tree.leaves(params["layers"])[0].shape[0]
    if layer_mask is None and n_stack != cfg.n_layers:
        layer_mask = jnp.arange(n_stack) < cfg.n_layers
    y, new_cache, aux = stack_apply(
        cfg, opts, params["layers"], x, pos=pos, mode=mode, cache=cache,
        shared_params=params.get("shared"), layer_offset=0,
        kv_offset=kv_offset, window=window, layer_mask=layer_mask)
    logits = lm_logits(cfg, params, y)
    return logits, new_cache, aux


def loss_fn(cfg: ArchConfig, opts: ModelOptions, params, batch: dict):
    logits, _, aux = forward(cfg, opts, params, batch, mode="train")
    loss = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
    if cfg.moe is not None:
        loss = loss + cfg.moe.load_balance_coef * aux / max(cfg.n_layers, 1)
    return loss


# ---------------------------------------------------------------------------
# The paper's 1.2M-param feed-forward workload (uniform hidden stack so it
# maps onto the same embed/stage/head pipeline structure).
# ---------------------------------------------------------------------------


def mlp_init(mlp_cfg, key, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    hidden_keys = jax.random.split(ks[1], mlp_cfg.n_hidden)

    def one(k):
        kw, = jax.random.split(k, 1)
        return {"w": _normal(kw, (mlp_cfg.d_hidden, mlp_cfg.d_hidden),
                             mlp_cfg.d_hidden, dtype),
                "b": jnp.zeros((mlp_cfg.d_hidden,), dtype)}

    return {
        "embed": {"w": _normal(ks[0], (mlp_cfg.d_in, mlp_cfg.d_hidden),
                               mlp_cfg.d_in, dtype),
                  "b": jnp.zeros((mlp_cfg.d_hidden,), dtype)},
        "layers": jax.vmap(one)(hidden_keys),
        "head": {"w": _normal(ks[2], (mlp_cfg.d_hidden, mlp_cfg.d_out),
                              mlp_cfg.d_hidden, dtype),
                 "b": jnp.zeros((mlp_cfg.d_out,), dtype)},
    }


def mlp_forward(params, x, layer_mask=None):
    h = jax.nn.relu(x @ params["embed"]["w"] + params["embed"]["b"])

    def body(carry, xs):
        if layer_mask is None:
            p = xs
            return jax.nn.relu(carry @ p["w"] + p["b"]), None
        p, m = xs
        y = jax.nn.relu(carry @ p["w"] + p["b"])
        return jnp.where(m, y, carry), None

    xs = params["layers"] if layer_mask is None else (params["layers"], layer_mask)
    h, _ = lax.scan(body, h, xs)
    return h @ params["head"]["w"] + params["head"]["b"]


def mlp_loss(params, batch):
    logits = mlp_forward(params, batch["x"])
    return cross_entropy(logits[:, None, :], batch["y"][:, None])
