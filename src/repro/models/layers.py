"""Core neural-net layers in pure JAX (no flax): norms, RoPE variants, GQA
attention (full / chunked-flash / decode), SwiGLU & GELU MLPs, top-k MoE with
scatter-based grouped dispatch, Mamba1 selective scan and Mamba2 SSD.

Everything is a pure function over explicit parameter pytrees so the Hydra
pipeline engine can stack layers along a leading axis and ``lax.scan`` them
per stage.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ModelOptions:
    """Execution knobs (not architecture): precision, remat, attention impl."""

    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    remat: bool = False  # activation-checkpoint each block
    attn_q_chunk: int = 2048  # flash-style chunking (jnp path)
    attn_kv_chunk: int = 1024
    use_flash_kernel: bool = False  # dispatch to Pallas kernel (TPU target)
    use_mamba_kernel: bool = False
    use_paged_kernel: bool = False  # paged decode/append attends straight
    # from the block pool (kernels/paged_attention.py) instead of gathering
    # each row's full logical K/V view; lowering picked by ops.paged_attention
    moe_capacity_factor: float = 1.25
    moe_expert_chunk: int = 0  # >0: scan expert FFNs in groups of this size
    # (bounds the fp32 weight-grad/gather transients to one group's worth)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps)).astype(dt) * w


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * lax.rsqrt(var + eps)).astype(dt) * w + b


def gated_rms_norm(x, gate, w, eps: float = 1e-5):
    """Mamba2 output norm: RMSNorm(x * silu(gate))."""
    return rms_norm(x * jax.nn.silu(gate), w, eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings (1d / 2d-half / M-RoPE)
# ---------------------------------------------------------------------------


def _rope_freqs(head_dim: int, theta: float, dtype=jnp.float32):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=dtype) / half))


def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions (..., s) -> cos/sin (..., s, head_dim//2)."""
    freqs = _rope_freqs(head_dim, theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def _rotate(x, cos, sin):
    """x (..., s, h, d) with cos/sin (..., s, d//2): rotate pairs (even, odd)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]  # broadcast over heads
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def apply_rope(x, positions, cfg: ArchConfig):
    """Apply the config's rotary variant. x: (b, s, h, hd).

    - "1d": standard rotary over the full head dim.
    - "2d": ChatGLM-style — rotary on the first half of the head dim only.
    - "mrope": Qwen2-VL — head-dim split in 3 sections driven by 3 position
      streams (temporal/height/width); ``positions`` has shape (3, b, s).
    - "none"/"learned": identity (positions handled at the embedding).
    """
    if cfg.rope in ("none", "learned"):
        return x
    hd = cfg.head_dim
    if cfg.rope == "1d":
        cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
        return _rotate(x, cos, sin)
    if cfg.rope == "2d":
        rot, keep = jnp.split(x, [hd // 2], axis=-1)
        cos, sin = rope_cos_sin(positions, hd // 2, cfg.rope_theta)
        return jnp.concatenate([_rotate(rot, cos, sin), keep], axis=-1)
    if cfg.rope == "mrope":
        # sections of the *pair* dimension (hd//2 pairs): 1/4 temporal, 3/8 h, 3/8 w
        half = hd // 2
        s_t = half // 4
        s_h = (half - s_t) // 2
        sections = [s_t, s_h, half - s_t - s_h]
        cos_parts, sin_parts = [], []
        for i, sec in enumerate(sections):
            freqs = _rope_freqs(hd, cfg.rope_theta)
            lo = sum(sections[:i])
            ang = positions[i].astype(jnp.float32)[..., None] * freqs[lo:lo + sec]
            cos_parts.append(jnp.cos(ang))
            sin_parts.append(jnp.sin(ang))
        cos = jnp.concatenate(cos_parts, axis=-1)
        sin = jnp.concatenate(sin_parts, axis=-1)
        return _rotate(x, cos, sin)
    raise ValueError(cfg.rope)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def repeat_kv(k, n_rep: int):
    """(b, s, h_kv, hd) -> (b, s, h_kv * n_rep, hd).

    Only for small oracle comparisons — production paths use grouped-einsum
    GQA (never materializing the repeated cache in HBM).
    """
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d)


def attention_reference(q, k, v, *, causal: bool, window: int = 0,
                        kv_offset: int = 0, kv_len=None):
    """Direct softmax attention with grouped-query support.

    q (b,sq,hq,hd), k/v (b,sk,hkv,hd) with hq = g·hkv. GQA is handled by a
    grouped einsum — the kv tensors are never expanded in memory (a 4-8 GB
    per-layer saving for the 8:1 GQA archs at 32k decode).

    ``kv_offset`` is the absolute position of q[0] minus that of k[0] (for
    decode, offset = cache length); a scalar, or a (b,) array when rows sit at
    different cache depths (ragged continuous-batching chunks). ``kv_len``
    optionally masks kv positions >= kv_len (ragged cache). ``window`` > 0
    restricts to a sliding window.
    """
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    off = jnp.asarray(kv_offset)
    if off.ndim == 0:
        qpos = (jnp.arange(sq) + off)[None, :, None]  # (1, sq, 1)
    else:
        qpos = off[:, None, None] + jnp.arange(sq)[None, :, None]  # (b, sq, 1)
    kpos = jnp.arange(sk)[None, None, :]  # (1, 1, sk)
    mask = jnp.ones((qpos.shape[0], sq, sk), dtype=bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    mask_b = mask[:, None, None]  # (b|1, 1, 1, sq, sk)
    if kv_len is not None:
        mask_b = mask_b & (kpos[None, None]
                           < kv_len[:, None, None, None, None])
    scores = jnp.where(mask_b, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(q.dtype), v)
    return out.reshape(b, sq, hq, hd)


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      kv_offset: int = 0, kv_len=None,
                      q_chunk: int = 2048, kv_chunk: int = 1024):
    """Flash-style online-softmax attention in pure jnp, O(chunk) memory,
    grouped-query aware (kv never expanded).

    Outer loop over q chunks (rematerialized), inner ``lax.scan`` over kv
    chunks with running (max, denom, accum). Matches ``attention_reference``.
    """
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    # pad to multiples
    sq_p = -(-sq // q_chunk) * q_chunk
    sk_p = -(-sk // kv_chunk) * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    n_q, n_k = sq_p // q_chunk, sk_p // kv_chunk
    scale = 1.0 / math.sqrt(hd)
    if kv_len is None:
        kv_len = jnp.full((b,), sk, jnp.int32)

    def q_block(qi, q_blk):
        q_start = qi * q_chunk
        qg = q_blk.reshape(b, q_chunk, hkv, g, hd)

        def kv_step(carry, ki):
            m, l, acc = carry  # (b, hkv, g, qc[, hd])
            k_start = ki * kv_chunk
            k_blk = lax.dynamic_slice_in_dim(kp, k_start, kv_chunk, axis=1)
            v_blk = lax.dynamic_slice_in_dim(vp, k_start, kv_chunk, axis=1)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                           k_blk).astype(jnp.float32) * scale
            off = jnp.asarray(kv_offset)
            qrow = q_start + jnp.arange(q_chunk)
            if off.ndim == 0:
                qpos = (qrow + off)[None, :, None]  # (1, qc, 1)
            else:
                qpos = off[:, None, None] + qrow[None, :, None]  # (b, qc, 1)
            kpos = (k_start + jnp.arange(kv_chunk))[None, None, :]
            msk = jnp.ones((qpos.shape[0], q_chunk, kv_chunk), bool)
            if causal:
                msk = msk & (kpos <= qpos)
            if window > 0:
                msk = msk & (kpos > qpos - window)
            msk_b = msk[:, None, None] & (
                kpos[None, None] < kv_len[:, None, None, None, None])
            s = jnp.where(msk_b, s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard all -inf rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(msk_b, p, 0.0)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, hd), jnp.float32)
        # flash-style backward: recompute the score block per kv step instead
        # of letting scan linearization stash every (q_chunk, kv_chunk) probs
        # matrix (which costs the full (sq, sk) scores in fp32)
        (m, l, acc), _ = lax.scan(jax.checkpoint(kv_step), (m0, l0, a0),
                                  jnp.arange(n_k))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (b, hkv, g, qc, hd) -> (b, qc, hq, hd)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, hq, hd)
        return out.astype(q.dtype)

    q_block = jax.checkpoint(q_block, static_argnums=())
    blocks = [q_block(qi, lax.dynamic_slice_in_dim(qp, qi * q_chunk, q_chunk, 1))
              for qi in range(n_q)]
    out = jnp.concatenate(blocks, axis=1)[:, :sq]
    return out


def attention(q, k, v, *, causal: bool, window: int = 0, kv_offset: int = 0,
              kv_len=None, opts: ModelOptions):
    """Dispatch: Pallas flash kernel (TPU target) / jnp chunked / direct."""
    sq, sk = q.shape[1], k.shape[1]
    if opts.use_flash_kernel and sq > 1 and kv_len is None \
            and jnp.ndim(kv_offset) == 0:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.flash_attention(
            q, k, v, causal=causal, window=window, kv_offset=kv_offset)
    # direct path only when the score tensor is small (decode q=1 scores are
    # (b, h, 1, sk) — linear in cache length); otherwise stream chunks
    if sq == 1 or sq * sk <= 512 * 512:
        return attention_reference(q, k, v, causal=causal, window=window,
                                   kv_offset=kv_offset, kv_len=kv_len)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             kv_offset=kv_offset, kv_len=kv_len,
                             q_chunk=opts.attn_q_chunk,
                             kv_chunk=opts.attn_kv_chunk)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_apply(p, x, act: str):
    if act == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w_down"])
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.gelu(h), p["w_down"])


# ---------------------------------------------------------------------------
# Mixture of Experts (scatter-based grouped dispatch; capacity-bounded)
# ---------------------------------------------------------------------------


def _expert_ffn(p_g, buckets_g, act: str):
    """Dense FFN over a group of experts. buckets_g (e, c, d)."""
    if act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buckets_g, p_g["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buckets_g, p_g["w_up"])
        return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p_g["w_down"])
    h = jnp.einsum("ecd,edf->ecf", buckets_g, p_g["w_up"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.gelu(h), p_g["w_down"])


def moe_apply(p, x, *, n_experts: int, top_k: int, capacity_factor: float,
              act: str = "swiglu", expert_chunk: int = 0):
    """Top-k MoE FFN. x (b, s, d) -> (b, s, d), plus load-balance aux loss.

    Tokens are scattered into per-expert capacity buckets (E, C, d) so the
    expert matmuls are dense and FLOPs stay ~capacity_factor × active — no
    E/k-fold dense-dispatch waste. Overflowing tokens are dropped (standard
    capacity semantics); the residual path keeps them represented.
    """
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = lax.top_k(probs, top_k)  # (t, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    capacity = max(int(capacity_factor * t * top_k / n_experts), top_k)
    # position of each (t, k) assignment within its expert's bucket
    flat_e = expert_idx.reshape(-1)  # (t*k,)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)  # (t*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot  # 1-based rank
    pos = (pos_in_e.sum(axis=-1) - 1).reshape(t, top_k)  # (t, k)
    keep = pos < capacity
    dest = jnp.where(keep, flat_e.reshape(t, top_k) * capacity + pos, -1)

    # scatter tokens into buckets (drop overflow via mode="drop")
    buckets = jnp.zeros((n_experts * capacity, d), x.dtype)
    src = jnp.repeat(xf[:, None, :], top_k, axis=1).reshape(t * top_k, d)
    buckets = buckets.at[dest.reshape(-1)].add(
        jnp.where(keep.reshape(-1, 1), src, 0), mode="drop")
    buckets = buckets.reshape(n_experts, capacity, d)

    # dense per-expert FFN — optionally scanned in expert groups so the fp32
    # weight-gradient / gathered-weight transients in backward are bounded by
    # one group (E=16 × (d, f) fp32 buffers otherwise dominate HBM)
    if expert_chunk and 0 < expert_chunk < n_experts \
            and n_experts % expert_chunk == 0:
        ng = n_experts // expert_chunk
        w = {k: p[k].reshape(ng, expert_chunk, *p[k].shape[1:])
             for k in ("w_gate", "w_up", "w_down") if k in p}
        b_g = buckets.reshape(ng, expert_chunk, capacity, d)

        @jax.checkpoint
        def group(_, inp):
            p_g, bg = inp
            return None, _expert_ffn(p_g, bg, act)

        _, y = lax.scan(group, None, (w, b_g))
        y = y.reshape(n_experts, capacity, d)
    else:
        y = _expert_ffn(p, buckets, act)
    y = y.reshape(n_experts * capacity, d)

    # gather back, weight by gates
    safe_dest = jnp.where(keep, dest, 0)
    gathered = y[safe_dest.reshape(-1)].reshape(t, top_k, d)
    gathered = jnp.where(keep[..., None], gathered, 0)
    out = jnp.einsum("tkd,tk->td", gathered, gate_vals.astype(x.dtype))

    # load-balance auxiliary loss (Switch-style)
    me = probs.mean(axis=0)  # (E,)
    ce = jax.nn.one_hot(expert_idx, n_experts).sum(axis=(0, 1)) / (t * top_k)
    aux = n_experts * jnp.sum(me * ce)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Mamba1 (selective scan) and Mamba2 (SSD, chunked)
# ---------------------------------------------------------------------------


def _causal_conv1d(x, w, b, state=None):
    """Depthwise causal conv. x (bt, s, c), w (c, width), state (bt, width-1, c).

    Returns (y, new_state) where new_state is the trailing (width-1) inputs.
    """
    width = w.shape[-1]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xe = jnp.concatenate([state, x], axis=1)
    # depthwise conv as sum of shifted slices (width is tiny, typically 4)
    s = x.shape[1]
    y = sum(xe[:, i:i + s] * w[:, i] for i in range(width))
    y = y + b
    new_state = xe[:, -(width - 1):] if width > 1 else state
    return y, new_state


def mamba1_mix(p, x, cfg: ArchConfig, ssm_state=None, conv_state=None,
               opts: Optional[ModelOptions] = None):
    """Mamba1 selective-scan mixer. x (b, s, d) -> (b, s, d).

    Train/prefill: chunked scan over time (rematerialized chunk bodies keep
    the (b, ck, di, n) intermediates transient) or the Pallas kernel. Decode
    (s==1): one recurrent step against (conv_state, ssm_state).
    Returns (y, new_ssm_state, new_conv_state).
    """
    s_cfg = cfg.ssm
    b, s, d = x.shape
    di, n = s_cfg.d_inner(cfg.d_model), s_cfg.d_state
    r = s_cfg.resolved_dt_rank(cfg.d_model)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xin, new_conv = _causal_conv1d(xin, p["conv_w"], p["conv_b"], conv_state)
    xin = jax.nn.silu(xin)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # (di, n)

    def ssm_inputs(x_chunk):
        """x_chunk (b, t, di) -> decay da (b,t,di,n), input dbx (b,t,di,n), C."""
        proj = jnp.einsum("bsi,ie->bse", x_chunk, p["x_proj"])
        dt_in, bmat, cmat = jnp.split(proj, [r, r + n], axis=-1)
        dt = jax.nn.softplus(
            jnp.einsum("bsr,ri->bsi", dt_in, p["dt_proj"]) + p["dt_bias"])
        da = jnp.exp(dt.astype(jnp.float32)[..., None] * a)
        dbx = (dt.astype(jnp.float32) * x_chunk.astype(jnp.float32))[..., None] \
            * bmat.astype(jnp.float32)[:, :, None, :]
        return da, dbx, cmat.astype(jnp.float32)

    if ssm_state is None:
        ssm_state = jnp.zeros((b, di, n), jnp.float32)

    if s == 1:
        da, dbx, cmat = ssm_inputs(xin)
        h = da[:, 0] * ssm_state + dbx[:, 0]  # (b, di, n)
        new_state = h
        y = jnp.einsum("bin,bn->bi", h, cmat[:, 0])[:, None]  # (b, 1, di)
    elif opts is not None and opts.use_mamba_kernel:
        from repro.kernels import ops as kernel_ops
        da, dbx, cmat = ssm_inputs(xin)
        y, new_state = kernel_ops.mamba_scan(da, dbx, cmat, ssm_state)
    else:
        ck = min(s_cfg.chunk_size, s)
        s_p = -(-s // ck) * ck
        xin_p = jnp.pad(xin, ((0, 0), (0, s_p - s), (0, 0)))
        nc = s_p // ck
        xin_c = xin_p.reshape(b, nc, ck, di).swapaxes(0, 1)  # (nc, b, ck, di)
        valid = (jnp.arange(s_p) < s).reshape(nc, ck)

        @jax.checkpoint
        def chunk_body(h, inp):
            x_chunk, v_chunk = inp
            da, dbx, cmat = ssm_inputs(x_chunk)
            # padded steps must not decay the carried state
            da = jnp.where(v_chunk[None, :, None, None], da, 1.0)
            dbx = jnp.where(v_chunk[None, :, None, None], dbx, 0.0)

            def step(hc, s_inp):
                da_t, dbx_t = s_inp
                hc = da_t * hc + dbx_t
                return hc, hc

            h_new, h_all = lax.scan(
                step, h, (da.swapaxes(0, 1), dbx.swapaxes(0, 1)))
            y_c = jnp.einsum("sbin,bsn->bsi", h_all, cmat)
            return h_new, y_c

        new_state, y_c = lax.scan(chunk_body, ssm_state, (xin_c, valid))
        y = y_c.swapaxes(0, 1).reshape(b, s_p, di)[:, :s]

    y = (y + xin.astype(jnp.float32) * p["D"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, new_state, new_conv


def mamba2_mix(p, x, cfg: ArchConfig, ssm_state=None, conv_state=None,
               opts: Optional[ModelOptions] = None):
    """Mamba2 (SSD) mixer, chunked "state-space dual" form. x (b, s, d).

    Scalar-per-head log-decay ``da``; state (b, nh, hd, n). Within a chunk the
    output is the attention-like form (C Bᵀ ⊙ L) X with the stable pairwise
    decay matrix L[t,u] = exp(cum_t − cum_u) (t ≥ u, exponent ≤ 0); states are
    carried across chunks with per-chunk decay. Returns
    (y, new_ssm_state, new_conv_state).
    """
    s_cfg = cfg.ssm
    b, s, d = x.shape
    di = s_cfg.d_inner(cfg.d_model)
    n, g = s_cfg.d_state, s_cfg.n_groups
    hd = s_cfg.head_dim
    nh = di // hd
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt_in = jnp.split(proj, [di, di + di + 2 * g * n], axis=-1)
    xbc, new_conv = _causal_conv1d(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xin, bmat, cmat = jnp.split(xbc, [di, di + g * n], axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) + p["dt_bias"])  # (b, s, nh)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # (nh,)
    xh = xin.reshape(b, s, nh, hd)
    rep = nh // g
    bm = jnp.repeat(bmat.reshape(b, s, g, n), rep, axis=2)  # (b, s, nh, n)
    cm = jnp.repeat(cmat.reshape(b, s, g, n), rep, axis=2)
    da = dt * a  # (b, s, nh) log-decay per step (<= 0)

    if ssm_state is None:
        ssm_state = jnp.zeros((b, nh, hd, n), jnp.float32)

    if s == 1:
        dbx = (dt[:, 0, :, None, None] * xh[:, 0, :, :, None].astype(jnp.float32)
               * bm[:, 0, :, None, :].astype(jnp.float32))  # (b, nh, hd, n)
        h = jnp.exp(da[:, 0])[:, :, None, None] * ssm_state + dbx
        new_state = h
        y = jnp.einsum("bhen,bhn->bhe", h, cm[:, 0].astype(jnp.float32))
        y = y.reshape(b, 1, di)
    else:
        ck = min(s_cfg.chunk_size, s)
        s_p = -(-s // ck) * ck
        pad = s_p - s
        da_p = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
        xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bm_p = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm_p = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        nc = s_p // ck
        to_c = lambda t: t.reshape(b, nc, ck, *t.shape[2:]).swapaxes(0, 1)
        da_c, xh_c, bm_c, cm_c, dt_c = map(to_c, (da_p, xh_p, bm_p, cm_p, dt_p))
        valid = (jnp.arange(s_p) < s).reshape(nc, ck)

        @jax.checkpoint
        def chunk_body(h_enter, inp):
            da_k, xh_k, bm_k, cm_k, dt_k, v_k = inp  # leading dim b, then ck
            # padded steps: no decay (log-decay 0), no input (x already 0)
            da_k = jnp.where(v_k[None, :, None], da_k, 0.0)
            cum = jnp.cumsum(da_k, axis=1)  # (b, ck, nh), inclusive
            # pairwise decay L[t,u] = exp(cum_t - cum_u) for u <= t (exp <= 1)
            diff = cum[:, :, None, :] - cum[:, None, :, :]  # (b, ck, ck, nh)
            tri = jnp.tril(jnp.ones((ck, ck), bool))[None, :, :, None]
            L = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
            # intra-chunk: scores[t,u] = (C_t·B_u) L[t,u] dt_u
            gb = jnp.einsum("bthn,buhn->btuh", cm_k, bm_k)
            scores = gb * L * dt_k[:, None, :, :]
            y_intra = jnp.einsum("btuh,buhe->bthe", scores,
                                 xh_k.astype(jnp.float32))
            # inter-chunk: decay state entering the chunk to each position
            y_inter = jnp.einsum("bthn,bhen->bthe",
                                 cm_k * jnp.exp(cum)[..., None], h_enter)
            # state at chunk end
            wexit = jnp.exp(cum[:, -1:, :] - cum) * dt_k  # (b, ck, nh)
            h_in = jnp.einsum("buh,buhe,buhn->bhen", wexit,
                              xh_k.astype(jnp.float32),
                              bm_k.astype(jnp.float32))
            h_exit = jnp.exp(cum[:, -1])[:, :, None, None] * h_enter + h_in
            return h_exit, (y_intra + y_inter)

        new_state, y_c = lax.scan(chunk_body, ssm_state,
                                  (da_c, xh_c.astype(jnp.float32),
                                   bm_c.astype(jnp.float32),
                                   cm_c.astype(jnp.float32), dt_c, valid))
        y = y_c.swapaxes(0, 1).reshape(b, s_p, nh, hd)[:, :s].reshape(b, s, di)

    y = (y + xh.reshape(b, s, di).astype(jnp.float32) *
         jnp.repeat(p["D"], hd)).astype(x.dtype)
    y = gated_rms_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, new_state, new_conv
