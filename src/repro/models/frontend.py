"""Modality frontend STUBS (per task spec: [audio]/[vlm] entries specify the
transformer backbone only; ``input_specs()`` provides precomputed frame/patch
embeddings).

These helpers generate synthetic frontend embeddings with the right
shapes/statistics for smoke tests and examples — a real deployment would
replace them with an EnCodec encoder (musicgen) or a ViT tower (qwen2-vl).
"""
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def synth_frontend_embeds(cfg: ArchConfig, key, batch: int,
                          dtype=jnp.float32):
    if cfg.frontend is None:
        return None
    return jax.random.normal(
        key, (batch, cfg.n_frontend_tokens, cfg.d_model)).astype(dtype)


def synth_mrope_positions(cfg: ArchConfig, batch: int, seq: int):
    """Text-style M-RoPE ids: all three sections share the linear position.

    A real VLM driver would give image patches (t, h, w) grid positions; for
    the backbone-only reproduction the linear fallback is what Qwen2-VL uses
    for pure-text segments.
    """
    base = jnp.broadcast_to(jnp.arange(seq), (batch, seq))
    return jnp.broadcast_to(base, (3, batch, seq))
