from repro.models.layers import ModelOptions  # noqa: F401
from repro.models import blocks, layers, lm  # noqa: F401
