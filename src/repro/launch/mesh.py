"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so that
importing this module never touches JAX device state — the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """The deployment mesh: one v5e pod 16×16 (data × model), or two pods
    2×16×16 (pod × data × model). 'model' is Hydra's pipeline-stage axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 4, multi_pod: bool = False):
    """Small mesh for CPU integration tests (fake host devices)."""
    if multi_pod:
        return make_mesh((2, n_data, n_model), ("pod", "data", "model"))
    return make_mesh((n_data, n_model), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
