import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))
# The two lines above MUST run before any jax import: jax locks the device
# count at first initialization, and the production dry-run needs 512
# placeholder host devices to build the 16x16 and 2x16x16 meshes.
# (REPRO_EXTRA_XLA_FLAGS lets the memory-debug tooling add dump flags.)

"""Multi-pod dry-run: lower + compile every (architecture × input-shape) cell
on the production mesh(es), prove memory fit, and extract roofline inputs.

Usage:
    python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
    python -m repro.launch.dryrun --all --both-meshes
    ... --set fsdp=0 --variant no_fsdp        # hillclimb variants

Each cell writes <out>/<mesh>/<variant>/<arch>__<shape>.json with the
compiled memory analysis, loop-aware HLO costs and the roofline row. Cells
already present are skipped (incremental, restartable).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.analysis import hlo as hlo_lib
from repro.analysis import roofline as roof
from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, SHAPES, shape_applicable
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import pipeline as pl
from repro.core.partitioner import plan_stages
from repro.core.scheduler import max_concurrent_trials
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes
from repro.models.layers import ModelOptions
from repro.optim.adamw import AdamW


def engine_for_cell(cfg: ArchConfig, shape: ShapeConfig, mesh,
                    overrides: dict) -> pl.EngineConfig:
    sizes = mesh_axis_sizes(mesh)
    n_stages = sizes["model"]
    data = sizes["data"]
    pods = sizes.get("pod", 1)
    dp = data * pods
    train = shape.kind == "train"
    replicated = shape.global_batch < dp
    mb = int(overrides.get("microbatch", 1))
    rows_per_replica = (shape.global_batch if replicated
                        else shape.global_batch // dp)
    n_micro = max(1, rows_per_replica // mb)
    # fsdp (weight sharding over the data axis + per-layer gather) is on for
    # serve as well: stage-sharding alone leaves e.g. deepseek-67b at
    # 8.3 GiB/chip of resident bf16 weights. The weight-resident variant for
    # small archs is a §Perf hillclimb knob (--set fsdp=0).
    base = pl.EngineConfig(
        n_trials=1, n_microbatches=n_micro, microbatch=mb,
        n_stages=n_stages, data_size=data, pod_size=pods,
        pod_axis="pod" if pods > 1 else None,
        fsdp=bool(int(overrides.get("fsdp", 1))),
        vocab_parallel=bool(int(overrides.get("vocab_parallel", 1))),
        batch_replicated=replicated,
        window=(cfg.sliding_window if shape.name == "long_500k" else 0),
        max_seq=shape.seq_len if shape.kind != "train" else 0,
        skip_bubbles=bool(int(overrides.get("skip_bubbles", 0))),
        layer_remat=bool(int(overrides.get("layer_remat", 1))),
    )
    chunks = int(overrides.get("prefill_chunks", 1))
    if shape.kind == "prefill" and chunks > 1 and cfg.frontend is None \
            and cfg.rope != "mrope":
        # sequence chunks become extra pipeline slots (Hydra slot-filling)
        base = dataclasses.replace(
            base, n_microbatches=base.n_microbatches * chunks,
            prefill_chunks=chunks)
    if train:
        k_cap = int(overrides.get("max_trials", 4))
        k = min(max_concurrent_trials(cfg, base, shape.seq_len, train=True),
                k_cap)
        k = max(int(overrides.get("n_trials", k)), 1)
        base = dataclasses.replace(base, n_trials=k)
    return base


def cell_structs(cfg: ArchConfig, shape: ShapeConfig, eng: pl.EngineConfig,
                 mesh, optimizer):
    """ShapeDtypeStructs (with shardings) for every input of the cell."""
    plan = plan_stages(cfg, eng.n_stages)
    max_pos = shape.seq_len if cfg.rope == "learned" else 0
    pstruct = pl.trial_params_struct(cfg, eng, plan, dtype=jnp.bfloat16,
                                     max_pos=max_pos)
    pspecs = pl.param_pspecs(cfg, eng)
    with_sh = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        pstruct, pspecs)

    mbg = eng.microbatch * (1 if eng.batch_replicated
                            else eng.data_size * eng.pod_size)
    K, M = eng.n_trials, eng.n_microbatches
    qlen = shape.seq_len if shape.kind != "decode" else 1
    if shape.kind == "prefill" and eng.prefill_chunks > 1:
        qlen = shape.seq_len // eng.prefill_chunks
    bspecs = pl.batch_pspecs(cfg, eng, train=shape.kind == "train")
    batch = {"tokens": jax.ShapeDtypeStruct((K, M, mbg, qlen), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((K, M, mbg, qlen), jnp.int32)
    elif shape.kind == "decode":
        batch["positions"] = jax.ShapeDtypeStruct((K, M, mbg), jnp.int32)
    if cfg.frontend is not None and shape.kind != "decode":
        batch["frontend_embeds"] = jax.ShapeDtypeStruct(
            (K, M, mbg, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.rope == "mrope" and shape.kind != "decode":
        batch["mrope_pos"] = jax.ShapeDtypeStruct((K, M, 3, mbg, qlen),
                                                  jnp.int32)
    if shape.kind == "prefill":
        bspecs.pop("positions", None)
    batch_sh = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        batch, {k: bspecs[k] for k in batch})

    out = {"params": with_sh, "batch": batch_sh}
    if shape.kind == "train":
        ostruct = optimizer.init_struct(pstruct)
        ospecs = optimizer.state_pspecs(pspecs)
        out["opt"] = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
            ostruct, ospecs)
        out["hparams"] = {
            "lr": jax.ShapeDtypeStruct((K,), jnp.float32),
            "wd": jax.ShapeDtypeStruct((K,), jnp.float32)}
        out["step"] = jax.ShapeDtypeStruct((), jnp.int32)
    else:
        cstruct = pl.serve_cache_struct(cfg, eng, dry_run=True)
        cspecs = pl.serve_cache_pspecs(cfg, eng)
        out["cache"] = jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
            cstruct, cspecs)
    return out


def run_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, mesh_name: str,
             overrides: dict) -> dict:
    t0 = time.time()
    eng = engine_for_cell(cfg, shape, mesh, overrides)
    opts = ModelOptions(param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
                        remat=bool(int(overrides.get("remat", 1))),
                        attn_q_chunk=int(overrides.get("attn_q_chunk", 1024)),
                        attn_kv_chunk=int(overrides.get("attn_kv_chunk", 512)),
                        moe_expert_chunk=int(overrides.get("moe_expert_chunk",
                                                           4)),
                        use_mamba_kernel=bool(int(
                            overrides.get("use_mamba_kernel", 0))),
                        use_flash_kernel=bool(int(
                            overrides.get("use_flash_kernel", 0))))
    optimizer = AdamW(grad_clip=1.0)
    structs = cell_structs(cfg, shape, eng, mesh, optimizer)

    if shape.kind == "train":
        fn = pl.make_train_step(cfg, opts, eng, mesh, optimizer, jit=False)
        jitted = jax.jit(fn, donate_argnums=(0, 1))
        lowered = jitted.lower(structs["params"], structs["opt"],
                               structs["batch"], structs["hparams"],
                               structs["step"])
    else:
        mode = "prefill" if shape.kind == "prefill" else "decode"
        fn = pl.make_serve_step(cfg, opts, eng, mesh, mode, jit=False)
        jitted = jax.jit(fn, donate_argnums=(1,))
        lowered = jitted.lower(structs["params"], structs["cache"],
                               structs["batch"])
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_d = {k: int(getattr(mem, k)) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "peak_memory_in_bytes", "generated_code_size_in_bytes")}
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    cond_w = (eng.n_slots / eng.n_ticks) if eng.skip_bubbles else 1.0
    costs = hlo_lib.analyze(txt, cond_weight=cond_w)
    wall = (eng.n_ticks / eng.n_slots) if eng.skip_bubbles else 1.0
    rl = roof.from_hlo_costs(cfg, shape, mesh_name,
                             n_chips=mesh.devices.size, costs=costs,
                             n_trials=eng.n_trials, wall_factor=wall)
    # per-device live bytes: args (params/opt/cache shards) + temps
    live = (mem_d["argument_size_in_bytes"] + mem_d["temp_size_in_bytes"]
            + mem_d["output_size_in_bytes"] - mem_d["alias_size_in_bytes"])
    # TPU-modeled bytes: the CPU backend's buffer assignment hoists fp32
    # converts of whole loop stashes out of the while loops (verified via
    # --xla_dump buffer dumps; EXPERIMENTS.md §Dry-run), which a TPU compile
    # schedules per-iteration. The analytic model prices the real residents:
    # param/opt shards + pipeline stash + per-layer transients + caches.
    from repro.core.scheduler import per_chip_bytes
    modeled = per_chip_bytes(cfg, eng, shape.seq_len,
                             train=shape.kind == "train").total \
        * eng.n_trials
    return {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
        "engine": {f.name: str(getattr(eng, f.name))
                   for f in dataclasses.fields(eng)},
        "n_chips": int(mesh.devices.size),
        "timings_s": {"lower": round(t_lower, 1),
                      "compile": round(t_compile, 1)},
        "memory_analysis": mem_d,
        "per_device_live_bytes": int(live),
        "fits_16GB": bool(live < 16 * 1024 ** 3),
        "modeled_bytes_per_device": int(modeled),
        "fits_16GB_modeled": bool(modeled < 16 * 1024 ** 3),
        "xla_cost_analysis_flops_bodies_once": float(ca.get("flops", -1.0)),
        "hlo_costs": {
            "flops_per_device": costs.flops,
            "collective_bytes_per_device": costs.collective_bytes,
            "hbm_bytes_per_device": costs.hbm_bytes,
            "bytes_by_kind": costs.bytes_by_kind,
            "count_by_kind": costs.count_by_kind,
            "while_trip_counts": costs.trip_counts,
        },
        "roofline": rl.row(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--paper-archs", action="store_true",
                    help="also run bert-large (paper workload)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--set", action="append", default=[],
                    help="engine override key=val (fsdp, microbatch, ...)")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = v

    archs = dict(ASSIGNED_ARCHS)
    if args.paper_archs:
        archs["bert-large"] = PAPER_ARCHS["bert-large"]
    if args.arch:
        archs = {args.arch: (ASSIGNED_ARCHS | PAPER_ARCHS)[args.arch]}
    shapes = [SHAPES[args.shape]] if args.shape else list(SHAPES.values())

    mesh_kinds = []
    if args.both_meshes:
        mesh_kinds = [False, True]
    else:
        mesh_kinds = [args.multi_pod]

    for multi_pod in mesh_kinds:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_name = "2x16x16" if multi_pod else "16x16"
        out_dir = os.path.join(args.out, mesh_name, args.variant)
        os.makedirs(out_dir, exist_ok=True)
        for name, cfg in archs.items():
            for shape in shapes:
                path = os.path.join(out_dir, f"{name}__{shape.name}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip-cached] {mesh_name} {name} {shape.name}")
                    continue
                ok, why = shape_applicable(cfg, shape)
                if not ok:
                    with open(path, "w") as f:
                        json.dump({"arch": name, "shape": shape.name,
                                   "mesh": mesh_name, "skipped": why}, f,
                                  indent=1)
                    print(f"[skip] {mesh_name} {name} {shape.name}: {why}")
                    continue
                print(f"[run ] {mesh_name} {name} {shape.name} ...",
                      flush=True)
                try:
                    res = run_cell(cfg, shape, mesh, mesh_name, overrides)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1)
                    r = res["roofline"]
                    print(f"   ok lower={res['timings_s']['lower']}s "
                          f"compile={res['timings_s']['compile']}s "
                          f"live={res['per_device_live_bytes']/2**30:.2f}GiB "
                          f"dom={r['dominant']} "
                          f"roofline={r['roofline_fraction']:.4f}",
                          flush=True)
                except Exception as e:
                    with open(path + ".err", "w") as f:
                        f.write(traceback.format_exc())
                    print(f"   FAIL {type(e).__name__}: {e}", flush=True)


if __name__ == "__main__":
    main()
