"""Training driver: Hydra model-selection training on a real mesh.

Runs end-to-end on whatever devices exist (CPU/TPU). For multi-device CPU
testing set XLA_FLAGS=--xla_force_host_platform_device_count=8 before launch.

    PYTHONPATH=src python -m repro.launch.train --arch chatglm3-6b --smoke \
        --trials 4 --steps 20 --n-data 2 --n-model 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import REGISTRY
from repro.core import pipeline as pl
from repro.core.hydra import HydraConfig, run_model_selection
from repro.core.trials import SuccessiveHalving, grid_search
from repro.launch.mesh import make_test_mesh
from repro.models.layers import ModelOptions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--trials", type=int, default=4)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--n-microbatches", type=int, default=4)
    ap.add_argument("--n-data", type=int, default=1)
    ap.add_argument("--n-model", type=int, default=1)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--asha", action="store_true",
                    help="successive halving instead of full grid")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    n_needed = args.n_data * args.n_model
    if jax.device_count() < n_needed:
        raise SystemExit(
            f"need {n_needed} devices, have {jax.device_count()} — set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    mesh = make_test_mesh(args.n_data, args.n_model)

    cfg = REGISTRY[args.arch]
    if args.smoke:
        cfg = cfg.reduced()
    opts = ModelOptions(remat=True)
    eng = pl.EngineConfig(
        n_trials=args.trials, n_microbatches=args.n_microbatches,
        microbatch=args.microbatch, n_stages=args.n_model,
        data_size=args.n_data, fsdp=args.fsdp)
    hc = HydraConfig(seq_len=args.seq_len, steps=args.steps,
                     ckpt_dir=args.ckpt_dir)
    lrs = [3e-3 * (0.5 ** i) for i in range(args.trials)]
    trials = grid_search(cfg.name, lrs)[:args.trials]

    t0 = time.time()
    strategy = SuccessiveHalving(base_steps=max(args.steps // 4, 1)) \
        if args.asha else None
    out = run_model_selection(cfg, opts, mesh, hc, trials, eng,
                              strategy=strategy)
    dt = time.time() - t0
    print(json.dumps({
        "best_trial": out["best"].spec.tag,
        "best_val_loss": out["best"].val_loss,
        "results": [{"tag": r.spec.tag, "lr": r.spec.lr,
                     "train_loss": r.train_loss, "val_loss": r.val_loss}
                    for r in out["all"]],
        "wall_s": round(dt, 1),
    }, indent=1))


if __name__ == "__main__":
    main()
