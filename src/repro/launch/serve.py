"""Serving driver: pipelined prefill + batched greedy decode.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --smoke \
        --n-data 2 --n-model 4 --prompt-len 16 --gen-len 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY
from repro.core import pipeline as pl
from repro.core.partitioner import plan_stages
from repro.launch.mesh import make_test_mesh
from repro.models.layers import ModelOptions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n-data", type=int, default=1)
    ap.add_argument("--n-model", type=int, default=1)
    ap.add_argument("--batch", type=int, default=4,
                    help="requests per data replica (pipeline slots)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=8)
    args = ap.parse_args()

    mesh = make_test_mesh(args.n_data, args.n_model)
    cfg = REGISTRY[args.arch]
    if args.smoke:
        cfg = cfg.reduced()
    max_seq = args.prompt_len + args.gen_len
    opts = ModelOptions()
    eng = pl.EngineConfig(
        n_trials=1, n_microbatches=args.batch, microbatch=1,
        n_stages=args.n_model, data_size=args.n_data,
        max_seq=max_seq, cache_dtype=jnp.float32)
    plan = plan_stages(cfg, eng.n_stages)
    key = jax.random.PRNGKey(0)
    params = pl.init_trial_params(cfg, eng, plan, key, max_pos=max_seq)

    prefill = pl.make_serve_step(cfg, opts, eng, mesh, "prefill")
    decode = pl.make_serve_step(cfg, opts, eng, mesh, "decode")

    mbg = eng.microbatch * eng.data_size
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (1, args.batch, mbg, args.prompt_len),
                           dtype=np.int32)
    cache = pl.serve_cache_struct(cfg, eng, dry_run=False)

    t0 = time.time()
    batch = {"tokens": jnp.asarray(prompts)}
    cache, tok, _ = prefill(params, cache, batch)
    generated = [np.asarray(tok)]
    pos = args.prompt_len
    for step in range(args.gen_len - 1):
        dbatch = {
            "tokens": jnp.asarray(generated[-1][..., None]),
            "positions": jnp.full((1, args.batch, mbg), pos, jnp.int32),
        }
        cache, tok, _ = decode(params, cache, dbatch)
        generated.append(np.asarray(tok))
        pos += 1
    dt = time.time() - t0
    gen = np.stack(generated, axis=-1)  # (1, M, mbg, gen_len)
    print(f"prompt shape {prompts.shape} -> generated {gen.shape} "
          f"in {dt:.2f}s ({gen.size / dt:.1f} tok/s on CPU)")
    for r in range(min(3, mbg)):
        print(f"  request[{r}]: ...{prompts[0, 0, r, -4:].tolist()} => "
              f"{gen[0, 0, r].tolist()}")


if __name__ == "__main__":
    main()
