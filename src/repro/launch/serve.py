"""Serving driver: continuous-batching engine over the Hydra pipeline.

Default mode streams a dynamic request trace (Poisson arrivals or a JSONL
replay) through :class:`repro.serve.ServeEngine` — slots are recycled the
round a request finishes and queued requests are admitted via chunked
prefill. ``--arches K`` co-serves K model variants from one gang: the slot
grid grows a trial axis, each request's ``arch`` id routes it to its own
variant's rows, and one SPMD program advances all K streams per tick.
``--static`` runs the old lockstep baseline on the same trace for
comparison.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --smoke \
        --n-data 2 --n-model 4 --slots 3 --n-requests 12 --rate 2.0

    # co-serve two variants from one gang, traffic skewed 3:1 toward arch 0
    ... python -m repro.launch.serve --arch chatglm3-6b --smoke \
        --arches 2 --arch-weights 3,1 --n-requests 16 --rate 2.0

    # paged multi-arch gang with shortest-prompt-first admission
    ... python -m repro.launch.serve --arch chatglm3-6b --smoke \
        --arches 2 --paged --policy sjf --n-requests 16

    # paged + radix prefix cache (cross-request KV sharing; plan the grid
    # for the traffic's expected prefix redundancy)
    ... python -m repro.launch.serve --arch chatglm3-6b --smoke \
        --paged --prefix-cache --expected-hit-rate 0.5 --n-requests 16

    # overcommit past the pool (preemptive retraction) with a host spill
    # tier absorbing retract payloads and evicted prefix blocks
    ... python -m repro.launch.serve --arch chatglm3-6b --smoke \
        --paged --prefix-cache --overcommit 1.5 --host-blocks 32

    # sliding-window serving (attention archs; window < prompt+gen)
    ... python -m repro.launch.serve --arch chatglm3-6b --smoke \
        --window 8 --n-requests 12

    # replay a recorded request stream (JSONL rows may carry arch/deadline)
    ... python -m repro.launch.serve --arch chatglm3-6b --smoke \
        --trace /tmp/stream.jsonl
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import pipeline as pl
from repro.core import scheduler as sched
from repro.core.partitioner import plan_stages
from repro.launch.mesh import make_test_mesh
from repro.models.layers import ModelOptions
from repro.obs import (Tracer, report, write_events, write_metrics,
                       write_perfetto)
from repro.serve import (POLICIES, Request, ServeEngine, blocks_for,
                         load_trace, poisson_trace, static_serve)


def build_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--n-data", type=int, default=1)
    ap.add_argument("--n-model", type=int, default=1)
    ap.add_argument("--arches", type=int, default=1,
                    help="model variants K co-served by one gang (trial "
                    "rows); requests are routed by their arch id")
    ap.add_argument("--arch-weights", default="",
                    help="comma arrival weights per arch for the synthetic "
                    "trace and capacity planning (default uniform)")
    ap.add_argument("--slots", type=int, default=0,
                    help="microbatch slots M per trial (0 = capacity-planned,"
                    " capped by --max-slots)")
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=1,
                    help="requests per (slot × data replica)")
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="max prompt length for the synthetic trace")
    ap.add_argument("--gen-len", type=int, default=8,
                    help="max generation budget for the synthetic trace")
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrivals per engine tick")
    ap.add_argument("--trace", default="",
                    help="JSONL request-stream to replay instead of the "
                    "synthetic Poisson trace")
    ap.add_argument("--prefill-chunks", type=int, default=2)
    ap.add_argument("--policy", choices=POLICIES, default="fcfs",
                    help="per-arch admission order: fcfs | sjf (shortest "
                    "prompt first) | deadline (earliest Request.deadline)")
    ap.add_argument("--deadline-slack", type=float, default=0.0,
                    help=">0: stamp synthetic requests with arrival + slack "
                    "* total_len deadlines (for --policy deadline)")
    ap.add_argument("--window", type=int, default=0,
                    help="sliding attention window in tokens (0 = full "
                    "attention; attention-family archs only)")
    ap.add_argument("--static", action="store_true",
                    help="run the lockstep static-batch baseline instead")
    cache = ap.add_mutually_exclusive_group()
    cache.add_argument("--paged", action="store_true",
                       help="paged KV-cache: per-trial block pools + "
                       "per-request block tables (admit by expected length)")
    cache.add_argument("--dense", action="store_true",
                       help="dense per-slot cache strips (the default)")
    adm = ap.add_mutually_exclusive_group()
    adm.add_argument("--fused-admission", action="store_true",
                     help="fold each round's prefill waves + decode step "
                     "into ONE mixed-tick pipeline call: prefilling rows "
                     "ride at their chunk width, decoding rows at qlen 1, "
                     "idle rows at 0 (attention-family archs; greedy tokens "
                     "stay bit-identical to the split schedule)")
    adm.add_argument("--split-admission", action="store_true",
                     help="one append call per chunk-length group plus a "
                     "separate decode call per round (the default)")
    attn = ap.add_mutually_exclusive_group()
    attn.add_argument("--paged-kernel", action="store_true",
                      help="paged decode/append attends straight from the "
                      "block pool via the Pallas paged-attention kernel "
                      "(trimmed block tables, O(live) work; interpret-mode/"
                      "jnp lowering on CPU — requires --paged)")
    attn.add_argument("--paged-gather", action="store_true",
                      help="paged decode/append gathers each row's full "
                      "max_seq logical K/V view before attending (the "
                      "default)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (--paged)")
    ap.add_argument("--n-blocks", type=int, default=0,
                    help="per-trial block-pool size (--paged with explicit "
                    "--slots; 0 = back every cell at max_seq)")
    ap.add_argument("--expected-seq", type=int, default=0,
                    help="expected request length for paged capacity "
                    "planning (0 = max_seq/2)")
    ap.add_argument("--overcommit", type=float, default=1.0,
                    help="paged admission headroom: commit up to this "
                    "fraction of each pool partition (1.0 = preemption-free; "
                    "> 1.0 enables retraction — on pool exhaustion the "
                    "youngest running request is preempted, swapped to the "
                    "host tier or replayed, and restored later)")
    ap.add_argument("--host-blocks", type=int, default=0,
                    help="host-memory spill tier capacity per pool partition "
                    "(--paged): evicted prefix-cache blocks spill to host "
                    "instead of being destroyed, and retraction swaps KV out "
                    "instead of recomputing (0 = no host tier)")
    ap.add_argument("--no-spill", action="store_true",
                    help="keep the host tier for retraction payloads only: "
                    "prefix-cache eviction destroys blocks instead of "
                    "spilling them")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="radix prefix cache over the paged block pool: "
                    "completed prompts stay cached and new requests reuse "
                    "shared-prefix KV blocks (requires --paged)")
    ap.add_argument("--expected-hit-rate", type=float, default=0.0,
                    help="expected prefix-cache hit fraction for paged "
                    "capacity planning (shrinks per-row expected demand)")
    ap.add_argument("--spec-draft", default="",
                    help="gang-speculative decoding: pair every target arch "
                    "with a drafter trial row holding THIS ArchConfig's "
                    "weights (must share the target's parameter skeleton and "
                    "vocab — heterogeneous drafter archs need ragged param "
                    "packing, see ROADMAP). Drafter rows autoregressively "
                    "propose --spec-gamma tokens; the target verifies them "
                    "in one append-mode call. Greedy tokens stay "
                    "bit-identical; drafter quality only moves the "
                    "acceptance rate")
    ap.add_argument("--spec-gamma", type=int, default=3,
                    help="draft tokens proposed per speculation round")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event / Perfetto JSON "
                    "timeline of the run here (one track per (k,m,b) slot "
                    "cell + pool/host-tier/queue counter tracks; open at "
                    "https://ui.perfetto.dev). Enables tracing")
    ap.add_argument("--events-out", default="",
                    help="write the raw structured event log (JSONL, one "
                    "event per line) here. Enables tracing")
    ap.add_argument("--metrics-out", default="",
                    help="write the run's metric registry snapshot (JSONL, "
                    "one metric per line) here")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def parse_weights(spec: str, k: int):
    if not spec:
        return None
    w = [float(x) for x in spec.split(",")]
    if len(w) != k:
        raise SystemExit(f"--arch-weights needs {k} comma-separated values, "
                         f"got {len(w)}")
    return w


def main():
    args = build_args().parse_args()
    if args.paged and args.static:
        raise SystemExit("--static is the dense lockstep baseline; "
                         "drop --paged")
    if args.prefix_cache and not args.paged:
        raise SystemExit("--prefix-cache shares paged KV blocks; add --paged")
    if args.overcommit > 1.0 and not args.paged:
        raise SystemExit(
            f"--overcommit {args.overcommit} > 1.0 admits past the block "
            f"pool and relies on retracting paged block commitments; dense "
            f"cache strips cannot be retracted — add --paged")
    if args.paged_kernel and not args.paged:
        raise SystemExit("--paged-kernel attends through block tables; "
                         "add --paged")
    if args.host_blocks < 0:
        raise SystemExit(f"--host-blocks must be >= 0, got {args.host_blocks}")
    if (args.host_blocks > 0 or args.no_spill) and not args.paged:
        raise SystemExit("--host-blocks/--no-spill manage the paged block "
                         "store's host tier; add --paged")
    if args.static and args.arches > 1:
        raise SystemExit("--static is single-arch lockstep batching; "
                         "multi-arch routing needs the continuous engine")
    if args.fused_admission and args.static:
        raise SystemExit("--fused-admission fuses the continuous engine's "
                         "round; drop --static")
    if args.spec_draft and args.static:
        raise SystemExit("--spec-draft speculates inside the continuous "
                         "engine's rounds; drop --static")
    if args.spec_draft and args.fused_admission:
        raise SystemExit("--spec-draft and --fused-admission both own the "
                         "round's ragged call structure; pick one")
    if args.spec_draft and args.spec_gamma < 1:
        raise SystemExit(f"--spec-gamma must be >= 1, got {args.spec_gamma}")
    weights = parse_weights(args.arch_weights, args.arches)
    mesh = make_test_mesh(args.n_data, args.n_model)
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    max_seq = args.prompt_len + args.gen_len
    opts = ModelOptions(use_paged_kernel=args.paged_kernel)
    base = pl.EngineConfig(
        n_trials=args.arches, n_microbatches=max(args.slots, 1),
        microbatch=args.microbatch, n_stages=args.n_model,
        data_size=args.n_data, max_seq=max_seq, cache_dtype=jnp.float32,
        prefill_chunks=args.prefill_chunks, paged=args.paged,
        block_size=args.block_size, window=args.window)
    if args.slots <= 0:
        exp = args.expected_seq or None
        mix = None
        if args.arches > 1:
            w = weights or [1.0] * args.arches
            mix = [(wi, exp or max_seq // 2) for wi in w]
        planned = sched.plan_serve_capacity(
            cfg, base, max_seq, paged=args.paged, expected_seq=exp,
            block_size=args.block_size, max_slots=args.max_slots, mix=mix,
            hit_rate=args.expected_hit_rate if args.paged else 0.0,
            overcommit=args.overcommit if args.paged else 1.0,
            host_blocks=args.host_blocks)
        slots = min(planned.n_microbatches, args.max_slots)
        for line in report.render_capacity_plan(planned, slots, args.paged):
            print(line)
        base = dataclasses.replace(base, n_microbatches=slots,
                                   n_blocks=planned.n_blocks,
                                   host_blocks=planned.host_blocks)
    elif args.paged:
        n_blocks = args.n_blocks
        if n_blocks <= 0:
            # default pool: back every cell at max_seq (worst case — still
            # paged mechanics; shrink with --n-blocks to see backpressure)
            dp = args.n_data
            per_row = blocks_for(max_seq, args.block_size)
            n_blocks = args.microbatch * args.slots * per_row * dp
        base = dataclasses.replace(base, n_blocks=n_blocks,
                                   host_blocks=args.host_blocks)
    eng = base
    spec_pairs = None
    if args.spec_draft:
        dcfg = get_config(args.spec_draft)
        if args.smoke:
            dcfg = dcfg.reduced()
        # drafter rows ride the same stacked param pytree (leading K axis),
        # so the drafter arch must share the target's parameter skeleton —
        # heterogeneous drafter archs need ragged param packing (ROADMAP)
        e1 = dataclasses.replace(eng, n_trials=1)

        def skeleton(c):
            shapes = jax.eval_shape(lambda: pl.init_trial_params(
                c, e1, plan_stages(c, eng.n_stages), jax.random.PRNGKey(0),
                max_pos=max_seq))
            return jax.tree.map(lambda x: (x.shape, x.dtype), shapes)

        if dcfg.vocab_size != cfg.vocab_size or skeleton(dcfg) != skeleton(cfg):
            raise SystemExit(
                f"--spec-draft {args.spec_draft}: drafter parameter skeleton "
                f"(or vocab) differs from {args.arch} — the trial axis "
                f"stacks rows of one shape, so a smaller drafter arch needs "
                f"ragged per-row param packing (tracked in ROADMAP.md); "
                f"pick an arch variant with an identical skeleton")
        # drafter rows mirror the target rows: target k drafts on row K + k
        spec_pairs = {k: args.arches + k for k in range(args.arches)}
        eng = dataclasses.replace(eng, n_trials=2 * args.arches)

    if args.trace:
        requests = load_trace(args.trace)
        too_long = [r.rid for r in requests if r.total_len > max_seq]
        if too_long:
            raise SystemExit(f"trace requests {too_long} exceed max_seq="
                             f"{max_seq}; raise --prompt-len/--gen-len")
        bad_arch = [r.rid for r in requests if r.arch >= args.arches]
        if bad_arch:
            raise SystemExit(f"trace requests {bad_arch} target arch ids >= "
                             f"--arches={args.arches}; raise --arches")
        if args.static:
            # fail before params/compile: lockstep groups need one length
            n_cells = eng.n_microbatches * eng.microbatch * eng.data_size
            for g0 in range(0, len(requests), n_cells):
                plens = {r.prompt_len for r in requests[g0:g0 + n_cells]}
                if len(plens) > 1:
                    raise SystemExit(
                        f"--static needs uniform prompt lengths per batch "
                        f"group; group at {g0} has {sorted(plens)} — drop "
                        f"--static or bucket the trace")
    elif args.static:
        # lockstep baseline needs uniform prompts; stagger the budgets
        rng = np.random.default_rng(args.seed)
        requests = [
            Request(i, rng.integers(0, cfg.vocab_size,
                                    (args.prompt_len,)).astype(np.int32),
                    int(rng.integers(max(1, args.gen_len // 2),
                                     args.gen_len + 1)))
            for i in range(args.n_requests)]
    else:
        requests = poisson_trace(
            args.n_requests, args.rate, cfg.vocab_size,
            prompt_lens=(max(args.prompt_len // 2, 1), args.prompt_len),
            gen_lens=(max(args.gen_len // 2, 1), args.gen_len),
            seed=args.seed, n_arches=args.arches, arch_weights=weights,
            deadline_slack=args.deadline_slack)

    plan = plan_stages(cfg, eng.n_stages)
    params = pl.init_trial_params(cfg, eng, plan,
                                  jax.random.PRNGKey(args.seed),
                                  max_pos=max_seq)

    tracing = bool(args.trace_out or args.events_out)
    if tracing and args.static:
        raise SystemExit("--trace-out/--events-out trace the continuous "
                         "engine's rounds; drop --static")
    tracer = Tracer() if tracing else None

    if args.static:
        completions, stats = static_serve(cfg, eng, mesh, params, requests,
                                          opts)
        mode = "static"
    else:
        engine = ServeEngine(cfg, eng, mesh, params, opts,
                             overcommit=args.overcommit, policy=args.policy,
                             prefix_cache=args.prefix_cache,
                             spill=not args.no_spill,
                             fused=args.fused_admission,
                             spec_gamma=args.spec_gamma if args.spec_draft
                             else 0, spec_pairs=spec_pairs, tracer=tracer)
        completions = engine.run(requests)
        stats = engine.stats
        mode = "continuous/paged" if args.paged else "continuous"
        if args.paged_kernel:
            mode += "+kernel"
        if args.fused_admission:
            mode += "+fused"
        if args.spec_draft:
            mode += f"+spec(gamma={args.spec_gamma})"
        if args.prefix_cache:
            mode += "+prefix-cache"
        if args.arches > 1:
            mode += f" x{args.arches}-arch gang"

    s = stats.summary()
    lines = report.render_completions(completions, multi_arch=args.arches > 1)
    lines += report.render_summary(mode, len(completions), s,
                                   policy=args.policy)
    if args.paged:
        lines += report.render_paged(s, eng.n_blocks, eng.block_size,
                                     eng.host_blocks, args.overcommit)
    if args.spec_draft and not args.static:
        lines += report.render_spec(s, engine.spec_stats.summary())
    if args.prefix_cache:
        lines += report.render_prefix(s)
    for line in lines:
        print(line)

    if tracer is not None:
        if args.trace_out:
            n = write_perfetto(tracer.events, args.trace_out)
            print(f"wrote {n} trace records -> {args.trace_out} "
                  f"(open at https://ui.perfetto.dev)")
        if args.events_out:
            n = write_events(tracer.events, args.events_out)
            print(f"wrote {n} events -> {args.events_out}")
    if args.metrics_out:
        n = write_metrics(stats.snapshot(), args.metrics_out)
        print(f"wrote {n} metrics -> {args.metrics_out}")


if __name__ == "__main__":
    main()
