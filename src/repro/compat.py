"""Version compatibility shims for the jax APIs the engine leans on.

The deployment toolchain tracks recent jax (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(axis_types=...)``); CI containers
may pin an older release where shard_map still lives in
``jax.experimental.shard_map`` (with ``check_rep`` instead of ``check_vma``)
and meshes take no axis types. Route every use through here so the rest of
the codebase is written against the modern surface only.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with fallback to ``jax.experimental.shard_map``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def differentiable_optimization_barrier() -> bool:
    """Whether ``lax.optimization_barrier`` has an AD rule in this release.

    Old releases can't differentiate through the barrier, so perf pins that
    sit on the gradient path (e.g. the FSDP gather hook) must drop it there.
    """
    from jax import lax
    from jax.interpreters import ad
    prim = getattr(lax, "optimization_barrier_p", None)
    return prim is not None and prim in ad.primitive_jvps


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types when the release supports them
    (older releases have no axis_types concept — plain meshes behave the
    same for our explicit shard_map programs)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)
