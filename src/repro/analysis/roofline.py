"""Three-term roofline model for TPU v5e, fed by the loop-aware HLO analyzer.

Per (arch × shape × mesh) cell:

    compute    = HLO_FLOPs_per_device                / peak_FLOPs_per_chip
    memory     = HBM_bytes_per_device (stream model) / HBM_bw_per_chip
    collective = collective_bytes_per_device         / ICI_link_bw

(The compiled module is the per-device SPMD program, so analyzer counts are
already per-device; the spec's "bytes / (chips × bw)" with global bytes is the
same quantity.)

Derived metrics:

    MODEL_FLOPS          = 6·N·D (train) or 2·N·D (forward-only), N = params
                           (active params for MoE), D = tokens per step per
                           trial × trials
    useful-compute ratio = MODEL_FLOPS / (HLO_FLOPs_per_device × chips)
                           (catches bubble/remat/dispatch waste)
    roofline_fraction    = ideal model-compute time / dominant term
                           (the §Perf score: 1.0 = all devices do only useful
                           math at peak, no memory/ICI stall)
"""
from __future__ import annotations

import dataclasses

from repro.analysis.hlo import HloCosts
from repro.configs.base import ArchConfig, ShapeConfig

# TPU v5e constants (per task spec)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s per chip
HBM_BW = 819e9  # B/s per chip
ICI_LINK_BW = 50e9  # B/s per link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_per_device: float
    collective_detail: dict
    # wall-clock factor: a bubble-skipping engine executes work only on its
    # n_slots valid ticks but still waits n_ticks of ring time per step —
    # skipped ticks save energy/HBM, not latency. Non-skip engines burn the
    # bubbles as (counted) garbage work, so their factor is 1. Back-to-back
    # streamed steps (decode serving; fill/drain-overlapped training) refill
    # the bubble with the next step's slots, recovering factor 1 — reported
    # as ``roofline_fraction_streamed``.
    wall_factor: float = 1.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s,
                   self.collective_s) * self.wall_factor

    @property
    def useful_ratio(self) -> float:
        total_hlo = self.hlo_flops_per_device * self.n_chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def ideal_model_time_s(self) -> float:
        return self.model_flops / (self.n_chips * PEAK_FLOPS_BF16)

    @property
    def roofline_fraction(self) -> float:
        t = self.bound_time_s
        return self.ideal_model_time_s / t if t else 0.0

    @property
    def roofline_fraction_streamed(self) -> float:
        t = max(self.compute_s, self.memory_s, self.collective_s)
        return self.ideal_model_time_s / t if t else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_device": self.hlo_flops_per_device,
            "useful_ratio": self.useful_ratio,
            "wall_factor": self.wall_factor,
            "roofline_fraction": self.roofline_fraction,
            "roofline_fraction_streamed": self.roofline_fraction_streamed,
            "collectives": self.collective_detail,
        }


def model_flops_for_cell(cfg: ArchConfig, shape: ShapeConfig,
                         n_trials: int = 1) -> float:
    n = cfg.active_param_count() if cfg.moe is not None else cfg.param_count()
    if shape.kind == "train":
        per_trial = 6.0 * n * shape.tokens_per_step
    else:  # prefill processes seq tokens; decode one token per sequence
        per_trial = 2.0 * n * shape.tokens_per_step
    return per_trial * n_trials


def from_hlo_costs(cfg: ArchConfig, shape: ShapeConfig, mesh_name: str,
                   n_chips: int, costs: HloCosts, n_trials: int = 1,
                   wall_factor: float = 1.0) -> Roofline:
    return Roofline(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, n_chips=n_chips,
        compute_s=costs.flops / PEAK_FLOPS_BF16,
        memory_s=costs.hbm_bytes / HBM_BW,
        collective_s=costs.collective_bytes / ICI_LINK_BW,
        model_flops=model_flops_for_cell(cfg, shape, n_trials),
        hlo_flops_per_device=costs.flops,
        collective_detail={k: round(v / 1e6, 2)
                           for k, v in costs.bytes_by_kind.items()},
        wall_factor=wall_factor,
    )


def format_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':9s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>10s} "
           f"{'useful':>7s} {'roofline':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:26s} {r['shape']:12s} {r['mesh']:9s} "
            f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
            f"{r['collective_s']:10.4f} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.3f} {r['roofline_fraction']:9.4f}")
    return "\n".join(lines)
