"""Loop-aware HLO analysis: FLOPs, collective traffic and an HBM-streaming
byte model, derived from compiled (post-SPMD) HLO text.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE, which makes it
useless for scanned pipelines (our tick loop × layer loop nest would be
undercounted by ~n_ticks × layers_per_stage). This module parses the HLO
module into computations, builds the call graph (while / fusion / call /
conditional), extracts known trip counts from while ``backend_config``, and
aggregates recursively with multipliers:

  * **flops** — dot/convolution FLOPs from result shape × contraction size;
  * **collective bytes** — per-device ICI traffic with ring-algorithm
    factors: all-reduce 2·B·(n−1)/n, all-gather/reduce-scatter B·(n−1)/n
    (B = full logical payload), collective-permute B (one hop);
  * **hbm bytes** — a streaming model: every materialized (non-fused) op
    reads its operands and writes its result once; fusions read unique
    parameters once and write outputs once. Upper-bounds true traffic
    (ignores on-chip reuse between ops) but is consistent across variants,
    which is what the §Perf iteration needs.

All counts are per-device per-execution (the module IS the per-device SPMD
program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
# headers may contain nested parens in the param tuple type, so match only
# the leading name and require the line to open a brace after an arrow
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                           r"(?:\{([^}]*)\}|%?([\w.\-]+))")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# HBM traffic model: count only *structural* data movement — matmul operand
# streams (weights + activations), conv, gather/scatter (MoE dispatch, embed),
# dynamic (update-)slices (KV caches, per-trial weight selection) and the
# sequence-mixing reduces. Elementwise chains are treated as fused (free):
# the compiled module here is CPU-optimized, whose fusion decisions differ
# from TPU, so per-op counting of elementwise traffic would be CPU-biased.
_TRAFFIC_OPS = {"dot", "convolution", "gather", "scatter", "dynamic-slice",
                "dynamic-update-slice", "reduce", "select-and-scatter",
                "pad", "concatenate"}


def _parse_shapes(text: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",")) if dims.strip() else ()
        out.append((dt, shape))
    return out


def _shape_bytes(shapes) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_shapes: list  # [(dtype, dims), ...]
    operands: list  # names
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list


def parse_module(hlo_text: str) -> dict:
    """Split module text into computations with parsed instructions."""
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            s = line.strip()
            m = _COMP_HDR_RE.match(s)
            if m and s.endswith("{") and "->" in s:
                cur = Computation(m.group(1), [])
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        # result type(s): everything before the opcode token
        op_m = re.search(r"\)\s*([a-z][a-z0-9\-]*)\(", " " + rest) or \
            re.search(r"(?:\]|\})\s*([a-z][a-z0-9\-]*)\(", rest) or \
            re.search(r"^\(?[a-z0-9]+\[[^\]]*\][^=]*?\s([a-z][a-z0-9\-]*)\(",
                      rest)
        opcode = op_m.group(1) if op_m else rest.split("(")[0].split()[-1]
        # shapes before the opcode occurrence are the result shapes
        idx = rest.find(opcode + "(")
        shape_txt = rest[:idx] if idx > 0 else rest
        result_shapes = _parse_shapes(shape_txt)
        # operands: %names inside the first (...) after opcode
        o_start = rest.find(opcode + "(")
        operands = []
        if o_start >= 0:
            depth = 0
            seg = ""
            for ch in rest[o_start + len(opcode):]:
                if ch == "(":
                    depth += 1
                    if depth == 1:
                        continue
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                seg += ch
            operands = _OPERAND_RE.findall(seg)
        cur.instrs.append(Instr(name, opcode, result_shapes, operands, line))
    return comps


def _dot_flops(instr: Instr, symtab: dict) -> float:
    """2 × |output| × contraction size (from lhs shape + contracting dims)."""
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    out_elems = 0
    for dt, shape in instr.result_shapes:
        n = 1
        for d in shape:
            n *= d
        out_elems += n
    contract = 1
    if m and instr.operands:
        lhs_shapes = symtab.get(instr.operands[0])
        if lhs_shapes:
            _, lhs_shape = lhs_shapes[0]
            for d in m.group(1).split(","):
                if d.strip() and int(d) < len(lhs_shape):
                    contract *= lhs_shape[int(d)]
    return 2.0 * out_elems * contract


def _conv_flops(instr: Instr, symtab: dict) -> float:
    out_elems = 0
    for dt, shape in instr.result_shapes:
        n = 1
        for d in shape:
            n *= d
        out_elems += n
    kern = symtab.get(instr.operands[1] if len(instr.operands) > 1 else "", [])
    k_elems = 1
    if kern:
        for d in kern[0][1]:
            k_elems *= d
    return 2.0 * out_elems * k_elems


def _collective_payload(instr: Instr, symtab: dict) -> tuple[str, float]:
    """Per-device ICI bytes for one executed collective (ring factors)."""
    kind = instr.opcode
    groups = _GROUPS_RE.search(instr.line)
    n = len(groups.group(1).split(",")) if groups else 2
    res_b = _shape_bytes(instr.result_shapes)
    if kind == "all-reduce":
        return kind, 2.0 * res_b * (n - 1) / n
    if kind == "all-gather":
        return kind, res_b * (n - 1) / n  # result is the gathered shape
    if kind == "reduce-scatter":
        return kind, res_b * (n - 1)  # result is the scattered shard
    if kind == "all-to-all":
        return kind, res_b * (n - 1) / n
    if kind == "collective-permute":
        return kind, float(res_b)
    return kind, float(res_b)


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    collective_bytes: float = 0.0
    hbm_bytes: float = 0.0
    bytes_by_kind: dict = dataclasses.field(default_factory=dict)
    count_by_kind: dict = dataclasses.field(default_factory=dict)
    trip_counts: list = dataclasses.field(default_factory=list)

    def add(self, other: "HloCosts", mult: float = 1.0):
        self.flops += other.flops * mult
        self.collective_bytes += other.collective_bytes * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k, v in other.bytes_by_kind.items():
            self.bytes_by_kind[k] = self.bytes_by_kind.get(k, 0) + v * mult
        for k, v in other.count_by_kind.items():
            self.count_by_kind[k] = self.count_by_kind.get(k, 0) + v * mult


def _called_comps(instr: Instr) -> list[str]:
    out = []
    for m in _CALL_ATTR_RE.finditer(instr.line):
        if m.group(1):
            out.extend(x.strip().lstrip("%")
                       for x in m.group(1).split(",") if x.strip())
        elif m.group(2):
            out.append(m.group(2))
    return out


def analyze(hlo_text: str, cond_weight: float = 1.0) -> HloCosts:
    """``cond_weight``: probability weight of the *heavier* branch of each
    conditional. 1.0 (default) = worst-case (correct when conds only mask
    padded layers). The bubble-skipping engine passes n_slots/n_ticks — each
    stage's valid fraction — so skipped fill/drain ticks are not billed."""
    comps = parse_module(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        m = re.match(r"^ENTRY\s+%?([\w.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break
    if entry is None:  # fall back: last computation
        entry = list(comps)[-1] if comps else None
    memo: dict[tuple, HloCosts] = {}
    all_trips: list[int] = []

    def comp_cost(name: str, inside_cond: bool = False) -> HloCosts:
        key = (name, inside_cond)
        if key in memo:
            return memo[key]
        memo[key] = HloCosts()  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        symtab = {i.name: i.result_shapes for i in comp.instrs}
        total = HloCosts()
        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                total.flops += _dot_flops(ins, symtab)
            elif op == "convolution":
                total.flops += _conv_flops(ins, symtab)
            if op in COLLECTIVE_OPS:
                kind, b = _collective_payload(ins, symtab)
                total.collective_bytes += b
                total.bytes_by_kind[kind] = total.bytes_by_kind.get(kind, 0) + b
                total.count_by_kind[kind] = total.count_by_kind.get(kind, 0) + 1
            # HBM streaming model (structural ops only; see _TRAFFIC_OPS)
            if op in _TRAFFIC_OPS:
                if op in ("dynamic-slice", "gather"):
                    # reads only the sliced region: result read + written
                    b = 2 * _shape_bytes(ins.result_shapes)
                elif op in ("dynamic-update-slice", "scatter"):
                    # read-modify-write of the update region only
                    upd = (symtab.get(ins.operands[1], [])
                           if len(ins.operands) > 1 else [])
                    b = 3 * _shape_bytes(upd)
                elif op in ("pad", "concatenate"):
                    b = 2 * _shape_bytes(ins.result_shapes)
                else:  # dot/conv/reduce/...: stream all operands + result
                    b = _shape_bytes(ins.result_shapes)
                    for o in ins.operands:
                        b += _shape_bytes(symtab.get(o, []))
                total.hbm_bytes += b
            # recurse into called computations
            callees = _called_comps(ins)
            if op == "while":
                trip_m = _TRIP_RE.search(ins.line)
                trip = int(trip_m.group(1)) if trip_m else 1
                all_trips.append(trip)
                body = None
                cond = None
                bm = re.search(r"body=%?([\w.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.line)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                if body:
                    total.add(comp_cost(body, inside_cond), trip)
                if cond:
                    total.add(comp_cost(cond, inside_cond), trip)
            elif op == "conditional":
                # weight only the OUTERMOST conditional level: the engine's
                # bubble-skip conds wrap the stage compute, whose *inner*
                # layer-mask conds must stay worst-case (else the validity
                # discount compounds to w² and under-counts real work)
                w = cond_weight if not inside_cond else 1.0
                branches = [comp_cost(c, True) for c in callees]
                if branches:
                    ordered = sorted(branches,
                                     key=lambda c: c.flops + c.hbm_bytes
                                     + c.collective_bytes, reverse=True)
                    total.add(ordered[0], w)
                    for b in ordered[1:]:
                        total.add(b, (1.0 - w) / max(len(ordered) - 1, 1))
            elif op in ("fusion", "call", "map", "async-start"):
                # recurse fully: fusions may contain dots (flops + traffic)
                for c in callees:
                    total.add(comp_cost(c, inside_cond), 1.0)
            # reduce/sort/scatter/collective `to_apply` bodies are scalar
            # lambdas — no traffic or flops worth counting; skip recursion
        memo[key] = total
        return total

    result = HloCosts()
    if entry:
        result.add(comp_cost(entry))
    result.trip_counts = all_trips
    return result


def summarize(costs: HloCosts) -> str:
    parts = [f"flops={costs.flops:.3e}",
             f"collective={costs.collective_bytes/1e9:.3f}GB",
             f"hbm~{costs.hbm_bytes/1e9:.3f}GB"]
    for k in sorted(costs.bytes_by_kind):
        parts.append(f"{k}={costs.bytes_by_kind[k]/1e9:.3f}GB"
                     f"×{costs.count_by_kind.get(k, 0):.0f}")
    return " ".join(parts)
