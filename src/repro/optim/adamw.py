"""AdamW with per-trial (vmapped) hyperparameters — pure JAX, no optax.

Hydra trains K trials in one SPMD program, so every hyperparameter that the
model-selection layer searches over (learning rate, weight decay, β1/β2) is a
(K,) array broadcast against the leading trial axis of each parameter leaf.
Optimizer state mirrors the parameter sharding exactly (ZeRO-1 falls out of
FSDP param sharding for free: sharded param shard ⇒ sharded m/v shard).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


def _bcast(vec, leaf):
    """(K,) -> (K, 1, 1, ...) matching leaf rank."""
    return vec.reshape(vec.shape + (1,) * (leaf.ndim - 1))


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------


def constant_schedule(step):
    return jnp.ones_like(step, jnp.float32)


def warmup_cosine_schedule(warmup: int, total: int, final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return fn


def warmup_linear_schedule(warmup: int, total: int):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        return warm * (1 - prog)
    return fn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0  # default; override per-trial via hparams["wd"]
    grad_clip: float = 0.0  # 0 = off; per-trial clip-by-global-norm
    schedule: Callable = dataclasses.field(default=constant_schedule)

    def init(self, params):
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": zeros,
                "v": jax.tree.map(jnp.zeros_like, zeros),
                "count": jnp.zeros((), jnp.int32)}

    def init_struct(self, params_struct):
        """ShapeDtypeStruct view of ``init`` (dry-run)."""
        z = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
            params_struct)
        return {"m": z, "v": z,
                "count": jax.ShapeDtypeStruct((), jnp.int32)}

    def state_pspecs(self, pspecs):
        from jax.sharding import PartitionSpec as P
        return {"m": pspecs, "v": pspecs, "count": P()}

    def update(self, params, grads, state, hparams, step,
               grad_norm: Optional[jnp.ndarray] = None):
        """One AdamW step. hparams: {"lr": (K,), optional "wd": (K,)}.

        ``grad_norm`` is the per-trial global gradient norm (K,), computed
        sharding-aware by the caller; used for clip-by-global-norm.
        """
        lr = hparams["lr"].astype(jnp.float32) * self.schedule(step)
        wd = hparams.get("wd")
        if wd is None:
            wd = jnp.full_like(lr, self.weight_decay)
        count = state["count"] + 1
        b1c = 1 - self.b1 ** count.astype(jnp.float32)
        b2c = 1 - self.b2 ** count.astype(jnp.float32)

        if self.grad_clip > 0 and grad_norm is not None:
            scale = jnp.minimum(1.0, self.grad_clip / (grad_norm + 1e-9))
        else:
            scale = jnp.ones_like(lr)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * _bcast(scale, g)
            m_new = self.b1 * m + (1 - self.b1) * g
            v_new = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mhat = m_new / b1c
            vhat = v_new / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps) \
                + _bcast(wd, p) * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - _bcast(lr, p) * delta
            return p_new.astype(p.dtype), m_new, v_new

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        params_new = jax.tree.unflatten(treedef, [o[0] for o in out])
        m_new = jax.tree.unflatten(treedef, [o[1] for o in out])
        v_new = jax.tree.unflatten(treedef, [o[2] for o in out])
        return params_new, {"m": m_new, "v": v_new, "count": count}


# ---------------------------------------------------------------------------
# SGD (for the paper's MLP accuracy-parity experiment: plain, no state)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SGD:
    momentum: float = 0.0

    def init(self, params):
        if self.momentum == 0.0:
            return {"count": jnp.zeros((), jnp.int32)}
        return {"mom": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32)}

    def state_pspecs(self, pspecs):
        from jax.sharding import PartitionSpec as P
        if self.momentum == 0.0:
            return {"count": P()}
        return {"mom": pspecs, "count": P()}

    def update(self, params, grads, state, hparams, step, grad_norm=None):
        lr = hparams["lr"].astype(jnp.float32)
        count = state["count"] + 1
        if self.momentum == 0.0:
            new = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - _bcast(lr, p) * g.astype(jnp.float32)
                              ).astype(p.dtype), params, grads)
            return new, {"count": count}
        mom = jax.tree.map(
            lambda mo, g: self.momentum * mo + g.astype(jnp.float32),
            state["mom"], grads)
        new = jax.tree.map(
            lambda p, mo: (p.astype(jnp.float32) - _bcast(lr, p) * mo
                           ).astype(p.dtype), params, mom)
        return new, {"mom": mom, "count": count}
