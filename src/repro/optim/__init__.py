from repro.optim.adamw import AdamW, SGD, warmup_cosine_schedule  # noqa: F401
