"""Sharded checkpointing: per-process npz shards + JSON manifest, with an
async writer that keeps the save off the training critical path.

Layout::

    <dir>/step_000100/
        manifest.json          {"step": 100, "leaves": [...], "procs": N}
        proc00000.npz          this process's addressable shard of each leaf

Multi-host semantics: every process saves only the shards it owns
(``addressable_shards``); restore re-assembles per-process and relies on the
deterministic mesh layout to place them. On this single-process container the
same code path runs with one shard file. Restart protocol: ``latest_step`` +
``restore`` resume a preempted run (see runtime/fault_tolerance.py).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _path_str(path) -> str:
    out = []
    for p in path:
        out.append(str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p)))
    return "/".join(out)


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [( _path_str(p), leaf) for p, leaf in flat], treedef


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None,
         process_index: int = 0, process_count: int = 1) -> str:
    """Synchronous save. Returns the checkpoint path."""
    named, _ = _flatten_with_names(tree)
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp_dir = step_dir + f".tmp{process_index}"
    os.makedirs(tmp_dir, exist_ok=True)
    arrays = {}
    for name, leaf in named:
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.name == "bfloat16":  # npz can't serialize ml_dtypes
            arrays[name + "::bf16"] = arr.view(np.uint16)
            continue
        arrays[name] = arr
    np.savez(os.path.join(tmp_dir, f"proc{process_index:05d}.npz"), **arrays)
    if process_index == 0:
        manifest = {"step": step, "leaves": [n for n, _ in named],
                    "procs": process_count, "extra": extra or {}}
        with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f)
    # atomic-ish rename (single process owns the final move)
    os.makedirs(ckpt_dir, exist_ok=True)
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.rename(tmp_dir, step_dir)
    return step_dir


def restore(ckpt_dir: str, step: int, template: Any,
            process_index: int = 0) -> Any:
    """Restore into the structure of ``template`` (values replaced)."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(step_dir, f"proc{process_index:05d}.npz")) as z:
        data = {}
        for k in z.files:
            if k.endswith("::bf16"):
                import ml_dtypes
                data[k[:-6]] = z[k].view(ml_dtypes.bfloat16)
            else:
                data[k] = z[k]
    named, treedef = _flatten_with_names(template)
    leaves = []
    for name, leaf in named:
        if name not in data:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = data[name]
        want = getattr(leaf, "shape", None)
        if want is not None and tuple(arr.shape) != tuple(want):
            raise ValueError(
                f"leaf {name!r}: checkpoint shape {arr.shape} != {want}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def manifest(ckpt_dir: str, step: int) -> dict:
    with open(os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")) as f:
        return json.load(f)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def cleanup(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(m.group(1)) for name in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", name)))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host then write on a background thread.

    ``save`` blocks only for device→host transfer of the current values (so
    the training step can donate/overwrite buffers), not for disk I/O.
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra)
                cleanup(self.ckpt_dir, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
